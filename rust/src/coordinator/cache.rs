//! Plan caching: plan-once-serve-many without hand-threading plans.
//!
//! [`super::ExecutionPlan`] already gives iterative apps plan reuse —
//! when they can hold onto the plan. Serving-style callers often cannot:
//! a CLI command, a request handler or a benchmark loop sees (matrix,
//! kernel) pairs arrive repeatedly with no good place to stash the plan
//! between calls. [`PlanCache`] closes that gap: plans are keyed by
//! (matrix fingerprint, kernel spec, system shape) and built on first
//! use, so every later call with an equal matrix and spec gets the
//! cached plan in O(nnz) fingerprint time instead of a full re-plan
//! (partitioning + per-DPU format conversion + transfer pricing).
//!
//! The cache is internally synchronized (`&self` API) and hands out
//! [`Arc`]s, so one cache can serve concurrent request threads — it is
//! what [`super::SpmvService`] keeps behind every [`MatrixHandle`]
//! (shareable across services via `Arc`). Builds are **single-flight**:
//! when several threads race on one key, exactly one plans while the
//! others block on a condvar and then share the built plan — an
//! expensive O(nnz)-plus-conversion plan is never computed twice for
//! equal content.
//!
//! [`MatrixHandle`]: super::MatrixHandle

use super::plan::ExecutionPlan;
use super::spec::KernelSpec;
use super::SpmvExecutor;
use crate::matrix::{CooMatrix, SpElem};
use crate::util::Result;
use crate::util::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::collections::{HashMap, HashSet, VecDeque};

/// Default capacity of [`PlanCache::new`], in plans.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 32;

struct Inner<T: SpElem> {
    map: HashMap<String, Arc<ExecutionPlan<T>>>,
    /// Keys currently being planned by some thread (single-flight
    /// markers; never present in `map` simultaneously).
    building: HashSet<String>,
    /// Insertion order for FIFO eviction (keys always present in `map`).
    order: VecDeque<String>,
    hits: u64,
    misses: u64,
    builds: u64,
}

/// A bounded, thread-safe cache of [`ExecutionPlan`]s keyed by matrix
/// fingerprint + kernel spec + system shape.
///
/// Plans depend only on the (matrix, spec, bus-shape) triple — never on
/// the input vector or the tasklet count — so the key carries exactly
/// the matrix [`CooMatrix::fingerprint`], every [`KernelSpec`] field and
/// the executor's `n_dpus` / `dpus_per_rank` / `bus_scale`. Eviction is
/// FIFO once `capacity` distinct plans are resident. Concurrent lookups
/// of one missing key build the plan exactly once (single-flight): the
/// first thread plans (1 miss, 1 build), the rest wait and hit.
pub struct PlanCache<T: SpElem> {
    inner: Mutex<Inner<T>>,
    /// Signaled whenever a build finishes (successfully or not) so
    /// single-flight waiters can re-check the map.
    built: Condvar,
    capacity: usize,
}

impl<T: SpElem> PlanCache<T> {
    /// Cache with the default capacity
    /// ([`DEFAULT_PLAN_CACHE_CAPACITY`]).
    pub fn new() -> PlanCache<T> {
        Self::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }

    /// Cache holding at most `capacity` plans (clamped to >= 1).
    pub fn with_capacity(capacity: usize) -> PlanCache<T> {
        PlanCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                building: HashSet::new(),
                order: VecDeque::new(),
                hits: 0,
                misses: 0,
                builds: 0,
            }),
            built: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The plan for (`spec`, `m`) on `exec`'s system: served from cache
    /// when an equal matrix/spec/system was planned before, built via
    /// [`SpmvExecutor::plan`] (and inserted) otherwise. Concurrent calls
    /// for one missing key plan exactly once; the waiters count as hits.
    pub fn plan(
        &self,
        exec: &SpmvExecutor,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
    ) -> Result<Arc<ExecutionPlan<T>>> {
        let key = Self::key(exec, spec, m);
        {
            let mut inner = self.lock();
            loop {
                if let Some(p) = inner.map.get(&key).cloned() {
                    inner.hits += 1;
                    return Ok(p);
                }
                if inner.building.contains(&key) {
                    // Someone else is planning this key: wait for their
                    // build to land, then re-check (the loop also covers
                    // spurious wakeups and failed builds, where one
                    // waiter takes over as the builder).
                    inner = self.built.wait(inner).expect("plan cache poisoned");
                    continue;
                }
                inner.misses += 1;
                inner.building.insert(key.clone());
                break;
            }
        }
        // Plan outside the lock: planning is O(nnz)-heavy and must not
        // serialize concurrent requests for *different* matrices. The
        // `building` marker keeps same-key racers parked meanwhile; the
        // guard releases it even if exec.plan panics (a wedged marker
        // would park every future lookup of this key forever).
        let mut guard = BuildGuard { cache: self, key: Some(key) };
        let built = exec.plan(spec, m);
        let key = guard.key.take().expect("build guard already disarmed");
        drop(guard);
        let mut inner = self.lock();
        inner.building.remove(&key);
        let out = match built {
            Err(e) => Err(e),
            Ok(p) => {
                let p = Arc::new(p);
                inner.builds += 1;
                if inner.map.len() >= self.capacity {
                    if let Some(old) = inner.order.pop_front() {
                        inner.map.remove(&old);
                    }
                }
                inner.map.insert(key.clone(), Arc::clone(&p));
                inner.order.push_back(key);
                Ok(p)
            }
        };
        drop(inner);
        self.built.notify_all();
        out
    }

    /// Resident plan count.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from cache since construction (or [`Self::clear`]),
    /// including single-flight waiters that shared another thread's build.
    pub fn hits(&self) -> u64 {
        self.lock().hits
    }

    /// Lookups that had to build a plan (single-flight: one per
    /// concurrent group).
    pub fn misses(&self) -> u64 {
        self.lock().misses
    }

    /// Successful plan builds since construction (or [`Self::clear`]) —
    /// equals [`Self::misses`] unless a build failed.
    pub fn builds(&self) -> u64 {
        self.lock().builds
    }

    /// Maximum resident plans.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop every resident plan that nothing outside the cache still
    /// references (its `Arc` strong count is 1 — the cache's own pin),
    /// returning how many were evicted. Counters are untouched.
    ///
    /// This is the handle-eviction hook for serving facades: when a
    /// tenant unloads ([`crate::coordinator::ShardedService::unload_tenant`]),
    /// the per-shard [`crate::coordinator::MatrixHandle`] pins drop, and
    /// this reclaims the now-orphaned plans instead of letting them
    /// squat in the FIFO until capacity pressure. Plans another tenant
    /// (or an in-flight request) still holds stay resident. Sound under
    /// concurrency: a plan whose only `Arc` lives in the locked map
    /// cannot gain a new reference while we hold the lock.
    pub fn evict_unreferenced(&self) -> usize {
        let mut guard = self.lock();
        let inner = &mut *guard;
        let before = inner.map.len();
        let map = &mut inner.map;
        inner.order.retain(|k| match map.get(k) {
            Some(p) if Arc::strong_count(p) == 1 => {
                map.remove(k);
                false
            }
            Some(_) => true,
            None => false,
        });
        before - map.len()
    }

    /// Drop every resident plan and reset the hit/miss/build counters.
    /// In-flight builds are unaffected (they land after the clear).
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.map.clear();
        inner.order.clear();
        inner.hits = 0;
        inner.misses = 0;
        inner.builds = 0;
    }

    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().expect("plan cache poisoned")
    }

    /// Cache key: matrix fingerprint + the full spec + the system-shape
    /// fields an [`ExecutionPlan`] is checked against at execute time.
    /// `Debug` on [`KernelSpec`] covers every spec field; `bus_scale`
    /// keys on its exact bits. Shape and nnz ride along next to the
    /// 64-bit hash so whole classes of fingerprint collisions (any two
    /// matrices differing in dimensions or population) cannot alias.
    fn key(exec: &SpmvExecutor, spec: &KernelSpec, m: &CooMatrix<T>) -> String {
        let cfg = &exec.sys.cfg;
        format!(
            "{:016x}:{}x{}n{}|d{}r{}b{:016x}|{:?}",
            m.fingerprint(),
            m.nrows(),
            m.ncols(),
            m.nnz(),
            cfg.n_dpus,
            cfg.dpus_per_rank,
            cfg.bus_scale.to_bits(),
            spec
        )
    }
}

impl<T: SpElem> Default for PlanCache<T> {
    fn default() -> PlanCache<T> {
        Self::new()
    }
}

/// Releases a key's single-flight `building` marker if the plan build
/// unwinds (panics) before the normal completion path disarms the
/// guard — parked same-key waiters then retake the build instead of
/// waiting forever.
struct BuildGuard<'a, T: SpElem> {
    cache: &'a PlanCache<T>,
    key: Option<String>,
}

impl<T: SpElem> Drop for BuildGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(key) = self.key.take() {
            // Unwinding: drop the marker and wake waiters. Plain lock()
            // (not the expect wrapper) — double-panicking here would
            // abort the process.
            if let Ok(mut inner) = self.cache.inner.lock() {
                inner.building.remove(&key);
            }
            self.cache.built.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    #[test]
    fn cache_hits_on_equal_matrix_and_spec() {
        let m = generate::uniform::<f64>(128, 128, 4, 5);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let cache = PlanCache::new();
        let p1 = cache.plan(&exec, &KernelSpec::csr_nnz(), &m).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // An equal (cloned) matrix hits: keys are content-based.
        let p2 = cache.plan(&exec, &KernelSpec::csr_nnz(), &m.clone()).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the resident plan");
        // The cached plan executes like a fresh one.
        let x = vec![1.0; 128];
        let fresh_plan = exec.plan(&KernelSpec::csr_nnz(), &m).unwrap();
        let fresh = fresh_plan.execute(&exec, &x).unwrap();
        let cached = p2.execute(&exec, &x).unwrap();
        assert_eq!(cached.y, fresh.y);
        assert_eq!(cached.breakdown, fresh.breakdown);
    }

    #[test]
    fn cache_misses_on_different_spec_matrix_or_system() {
        let m = generate::uniform::<f64>(96, 96, 4, 5);
        let exec8 = SpmvExecutor::new(PimSystem::with_dpus(8));
        let cache = PlanCache::new();
        cache.plan(&exec8, &KernelSpec::csr_nnz(), &m).unwrap();
        cache.plan(&exec8, &KernelSpec::coo_nnz(), &m).unwrap();
        let m2 = generate::uniform::<f64>(96, 96, 4, 6);
        cache.plan(&exec8, &KernelSpec::csr_nnz(), &m2).unwrap();
        let exec16 = SpmvExecutor::new(PimSystem::with_dpus(16));
        cache.plan(&exec16, &KernelSpec::csr_nnz(), &m).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 4));
        assert_eq!(cache.builds(), 4);
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn capacity_evicts_fifo() {
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let cache = PlanCache::with_capacity(2);
        let ms: Vec<_> =
            (0..3).map(|s| generate::uniform::<f64>(64, 64, 3, s as u64)).collect();
        for m in &ms {
            cache.plan(&exec, &KernelSpec::coo_row(), m).unwrap();
        }
        assert_eq!(cache.len(), 2);
        // ms[0] was evicted -> miss; ms[2] is resident -> hit.
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[2]).unwrap();
        assert_eq!(cache.hits(), 1);
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[0]).unwrap();
        assert_eq!(cache.misses(), 4);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
        assert_eq!(cache.builds(), 0);
    }

    #[test]
    fn eviction_order_is_strict_insertion_order() {
        // Insert A, B (capacity 2), then C: A (oldest) must go, B and C
        // must survive; re-inserting A then evicts B (not C). FIFO is by
        // insertion, not by recency of use: touching A before inserting
        // C must not save it.
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let cache = PlanCache::with_capacity(2);
        let ms: Vec<_> =
            (0..3).map(|s| generate::uniform::<f64>(48, 48, 3, 100 + s as u64)).collect();
        let pa = cache.plan(&exec, &KernelSpec::coo_row(), &ms[0]).unwrap();
        let pb = cache.plan(&exec, &KernelSpec::coo_row(), &ms[1]).unwrap();
        // Touch A (a hit) — FIFO ignores it.
        let pa2 = cache.plan(&exec, &KernelSpec::coo_row(), &ms[0]).unwrap();
        assert!(Arc::ptr_eq(&pa, &pa2));
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[2]).unwrap(); // evicts A
        let hits_before = cache.hits();
        let pb2 = cache.plan(&exec, &KernelSpec::coo_row(), &ms[1]).unwrap(); // B resident
        assert!(Arc::ptr_eq(&pb, &pb2), "B must have survived A's eviction");
        assert_eq!(cache.hits(), hits_before + 1);
        let misses_before = cache.misses();
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[0]).unwrap(); // A rebuilt, evicts B
        assert_eq!(cache.misses(), misses_before + 1);
        let misses_before = cache.misses();
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[1]).unwrap(); // B gone again
        assert_eq!(cache.misses(), misses_before + 1);
    }

    #[test]
    fn evict_unreferenced_drops_only_orphaned_plans() {
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let cache: PlanCache<f64> = PlanCache::new();
        let ma = generate::uniform::<f64>(64, 64, 3, 1);
        let mb = generate::uniform::<f64>(64, 64, 3, 2);
        let pa = cache.plan(&exec, &KernelSpec::coo_row(), &ma).unwrap();
        drop(cache.plan(&exec, &KernelSpec::coo_row(), &mb).unwrap());
        assert_eq!(cache.len(), 2);
        // `pa` is still pinned by this test (a stand-in for a loaded
        // handle); only `mb`'s plan is orphaned.
        assert_eq!(cache.evict_unreferenced(), 1);
        assert_eq!(cache.len(), 1);
        let hits = cache.hits();
        cache.plan(&exec, &KernelSpec::coo_row(), &ma).unwrap();
        assert_eq!(cache.hits(), hits + 1, "pinned plan must remain resident");
        drop(pa);
        // Both references gone now (the re-lookup Arc was dropped too).
        assert_eq!(cache.evict_unreferenced(), 1);
        assert!(cache.is_empty());
        assert_eq!(cache.evict_unreferenced(), 0);
    }

    #[test]
    fn parallel_loads_of_one_matrix_plan_once() {
        // Single-flight: N threads racing on one (matrix, spec, system)
        // key must produce exactly one build / one miss; everyone shares
        // the same Arc.
        const THREADS: usize = 8;
        let m = generate::scale_free::<f64>(400, 400, 6, 0.6, 9);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let cache: PlanCache<f64> = PlanCache::new();
        let plans: Vec<Arc<ExecutionPlan<f64>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    let (cache, exec, m) = (&cache, &exec, &m);
                    s.spawn(move || cache.plan(exec, &KernelSpec::coo_nnz(), m).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(cache.builds(), 1, "concurrent loads must plan once");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), (THREADS - 1) as u64);
        assert_eq!(cache.len(), 1);
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p), "all threads share one plan");
        }
    }

    #[test]
    fn parallel_loads_of_distinct_matrices_do_not_serialize_counts() {
        // Different keys in parallel: every thread builds its own plan
        // (no single-flight interference across keys) and the counters
        // add up exactly.
        const THREADS: usize = 6;
        let ms: Vec<_> = (0..THREADS)
            .map(|s| generate::uniform::<f64>(96, 96, 4, 50 + s as u64))
            .collect();
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let cache: PlanCache<f64> = PlanCache::new();
        std::thread::scope(|s| {
            for m in &ms {
                let (cache, exec) = (&cache, &exec);
                s.spawn(move || {
                    // Two lookups per thread: the second is a guaranteed
                    // hit for this thread's own key.
                    cache.plan(exec, &KernelSpec::csr_nnz(), m).unwrap();
                    cache.plan(exec, &KernelSpec::csr_nnz(), m).unwrap();
                });
            }
        });
        assert_eq!(cache.builds(), THREADS as u64);
        assert_eq!(cache.misses(), THREADS as u64);
        assert_eq!(cache.hits(), THREADS as u64);
        assert_eq!(cache.len(), THREADS);
    }

    #[test]
    fn failed_builds_release_the_single_flight_marker() {
        // A 2D spec whose stripe count cannot divide the DPU grid fails
        // to plan; the failure must not wedge later lookups of the same
        // key (the building marker is released and retries re-plan).
        let m = generate::uniform::<f64>(64, 64, 4, 3);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(6));
        let bad = KernelSpec::two_d(crate::matrix::Format::Coo, 4); // 4 !| 6
        let cache: PlanCache<f64> = PlanCache::new();
        assert!(cache.plan(&exec, &bad, &m).is_err());
        assert!(cache.plan(&exec, &bad, &m).is_err(), "retry must not deadlock");
        assert_eq!(cache.builds(), 0);
        assert_eq!(cache.misses(), 2, "each failed attempt is a miss");
        assert!(cache.is_empty());
        // A good spec still works afterwards.
        assert!(cache.plan(&exec, &KernelSpec::coo_row(), &m).is_ok());
        assert_eq!(cache.builds(), 1);
    }
}
