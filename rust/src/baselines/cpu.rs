//! Multithreaded host-CPU CSR SpMV — the measured processor-centric
//! baseline (the paper uses MKL on a Xeon; same algorithm class:
//! row-parallel CSR with static nnz-balanced row ranges).

use crate::matrix::{CsrMatrix, SpElem};
use crate::partition::balance::split_weighted;
use std::time::Instant;

/// Result of a measured CPU SpMV run.
#[derive(Clone, Debug)]
pub struct CpuRun<T> {
    pub y: Vec<T>,
    /// Wall-clock seconds per iteration (best of `iters`).
    pub seconds: f64,
    pub threads: usize,
}

impl<T> CpuRun<T> {
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            2.0 * nnz as f64 / self.seconds / 1e9
        }
    }
}

/// Single-threaded CSR SpMV into a pre-allocated output (hot loop).
fn spmv_range<T: SpElem>(m: &CsrMatrix<T>, x: &[T], y: &mut [T], r0: usize, r1: usize) {
    for r in r0..r1 {
        let (cols, vals) = m.row(r);
        let mut acc = T::zero();
        for (c, v) in cols.iter().zip(vals) {
            acc = T::mac(acc, *v, x[*c as usize]);
        }
        y[r - r0] = acc;
    }
}

/// Run `iters` SpMV iterations on `threads` host threads; returns the
/// exact result and the best per-iteration wall time (standard practice
/// for memory-bound microbenchmarks: best-of filters scheduler noise).
pub fn spmv_parallel<T: SpElem>(
    m: &CsrMatrix<T>,
    x: &[T],
    threads: usize,
    iters: usize,
) -> CpuRun<T> {
    assert!(threads > 0 && iters > 0);
    assert_eq!(x.len(), m.ncols());
    let weights: Vec<usize> = (0..m.nrows()).map(|r| m.row_nnz(r)).collect();
    let ranges = split_weighted(&weights, threads);

    let mut y = vec![T::zero(); m.nrows()];
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        // Scoped threads write disjoint row ranges of y.
        let mut parts: Vec<&mut [T]> = Vec::with_capacity(threads);
        {
            let mut rest: &mut [T] = &mut y;
            let mut offset = 0usize;
            for range in &ranges {
                let (head, tail) = rest.split_at_mut(range.end - offset);
                parts.push(head);
                rest = tail;
                offset = range.end;
            }
        }
        std::thread::scope(|s| {
            for (range, part) in ranges.iter().zip(parts) {
                s.spawn(move || spmv_range(m, x, part, range.start, range.end));
            }
        });
        best = best.min(t0.elapsed().as_secs_f64());
    }
    CpuRun { y, seconds: best, threads }
}

/// Convenience: number of hardware threads available.
pub fn hw_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, CsrMatrix};

    #[test]
    fn parallel_matches_serial() {
        let m = generate::scale_free::<f64>(2000, 2000, 8, 0.6, 3);
        let csr = CsrMatrix::from_coo(&m);
        let x: Vec<f64> = (0..2000).map(|i| (i % 17) as f64).collect();
        for threads in [1, 2, 4, 7] {
            let run = spmv_parallel(&csr, &x, threads, 2);
            assert_eq!(run.y, csr.spmv(&x), "threads={threads}");
            assert!(run.seconds > 0.0);
        }
    }

    #[test]
    fn works_with_more_threads_than_rows() {
        let m = generate::banded::<f32>(5, 2, 1);
        let csr = CsrMatrix::from_coo(&m);
        let run = spmv_parallel(&csr, &vec![1.0f32; 5], 16, 1);
        assert_eq!(run.y, csr.spmv(&vec![1.0f32; 5]));
    }

    #[test]
    fn gflops_positive() {
        let m = generate::uniform::<f64>(1024, 1024, 16, 2);
        let csr = CsrMatrix::from_coo(&m);
        let run = spmv_parallel(&csr, &vec![1.0; 1024], 2, 3);
        assert!(run.gflops(m.nnz()) > 0.0);
    }
}
