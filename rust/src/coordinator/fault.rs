//! Deterministic fault injection for the sharded serving tier.
//!
//! Chaos testing a *simulation* has one enormous advantage over chaos
//! testing production: faults can be exactly reproducible. This module
//! keeps that property end to end:
//!
//! * [`FaultInjector`] is the hook trait [`super::ShardedService`]
//!   consults from its dispatcher (before scattering a request across
//!   the shard backends) and its gather thread (before reassembling the
//!   sub-responses). Production builds configure no injector — the hook
//!   is an `Option<Arc<dyn FaultInjector>>` checked once per request,
//!   so the fault machinery costs nothing when unused.
//! * [`Fault`] is the taxonomy: kill a backend shard service, delay a
//!   stage, drop a sub-response, or wedge a shard outright. Every fault
//!   is *recoverable by construction* — supervision respawns killed
//!   backends from the shared plan cache and re-scatters the affected
//!   sub-requests, so gathered outputs stay bit-identical to the
//!   fault-free oracle (locked by `tests/chaos_equivalence.rs`).
//! * Fault keys are **backend slot indices**. Under a 2D grid with
//!   replication ([`super::GridSpec`]) slot `i` names the replica at
//!   grid coordinate `(band, col, replica)` via the fixed linear
//!   layout `i = (band * C + col) * K + replica` — so a seeded
//!   schedule replays on identical grid coordinates run after run,
//!   and a plan written for an S-shard row-only facade (`C = K = 1`)
//!   keeps its meaning unchanged (slot `i` = band `i`).
//! * [`FaultPlan`] is the standard injector: an explicit per-ticket
//!   fault schedule, buildable by hand ([`FaultPlan::on_dispatch`] /
//!   [`FaultPlan::on_gather`]) or generated from a seed
//!   ([`FaultPlan::random`]) via the crate's deterministic PRNG. The
//!   same seed always yields the same schedule — a failing chaos run
//!   prints its seed and is reproduced with one command.
//!
//! The slow-tenant *flood* scenario needs no injector: floods are
//! driven from the submit side (a tenant outrunning its queue-depth
//! cap) and answered by admission control with
//! [`super::Response::Overloaded`]; the chaos suite covers it next to
//! the injected faults.

use crate::util::rng::Rng;
use std::collections::HashMap;
use std::fmt;

/// One injected fault. `shard` indexes the facade's backend slots
/// (`0..rows*cols*replicas` in [`super::GridSpec`]'s linear layout
/// `(band * C + col) * K + replica`; plain row sharding is the
/// `C = K = 1` case where slot `i` is row band `i`). Faults naming a
/// slot the current request does not touch — or one past the end of
/// the grid — are ignored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Kill backend `shard`: the service object is torn down and its
    /// in-flight sub-responses are lost. Supervision respawns the
    /// backend from the shared plan cache (re-planning equal slices is
    /// a cache *hit*, never a rebuild) and the affected sub-requests
    /// are re-scattered.
    KillShard { shard: usize },
    /// Sleep `millis` before the stage proceeds (a delayed stage
    /// completion). Changes timing only — results are bit-identical.
    Delay { millis: u64 },
    /// Drop shard `shard`'s completed sub-response on the floor
    /// (gather-side only): the gather thread discards it and
    /// re-scatters that shard's sub-request to the (live) backend.
    DropCompletion { shard: usize },
    /// Wedge shard `shard`: its sub-response never arrives. With a
    /// configured `wait_timeout` the gather thread fails the request
    /// with a typed `ShardTimeout` naming the shard; without one the
    /// stall is ignored (the pre-timeout facade would hang forever —
    /// exactly the hazard `wait_timeout` exists to fix).
    StallShard { shard: usize },
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::KillShard { shard } => write!(f, "kill-shard({shard})"),
            Fault::Delay { millis } => write!(f, "delay({millis}ms)"),
            Fault::DropCompletion { shard } => write!(f, "drop-completion({shard})"),
            Fault::StallShard { shard } => write!(f, "stall-shard({shard})"),
        }
    }
}

/// Hook consulted by the sharded facade's dispatcher and gather
/// threads. Implementations must be cheap and deterministic: the hooks
/// are called once per scheduled request per stage, on the stage's own
/// thread.
pub trait FaultInjector: Send + Sync {
    /// Faults to inject when the dispatcher picks up facade ticket
    /// `ticket`, *before* it scatters sub-requests. `KillShard` here
    /// exercises the detect-dead-backend path: the scatter finds the
    /// slot dead and supervision respawns it first.
    fn at_dispatch(&self, ticket: u64) -> Vec<Fault> {
        let _ = ticket;
        Vec::new()
    }

    /// Faults to inject when the gather thread starts reassembling
    /// facade ticket `ticket`. `KillShard` here loses the shard's
    /// in-flight sub-response (respawn + re-scatter recovers it);
    /// `DropCompletion` discards the sub-response after completion.
    fn at_gather(&self, ticket: u64) -> Vec<Fault> {
        let _ = ticket;
        Vec::new()
    }
}

/// An explicit, reproducible fault schedule keyed by facade ticket id.
///
/// Ticket ids are assigned by the facade in submission order starting
/// at 1, so a schedule written against "the 3rd submitted request" is
/// stable run to run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    dispatch: HashMap<u64, Vec<Fault>>,
    gather: HashMap<u64, Vec<Fault>>,
}

/// The named chaos scenarios the differential suite sweeps. Each maps
/// to a one-fault [`FaultPlan`] shape; [`FaultPlan::random`] mixes them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// A backend is dead when the dispatcher tries to scatter to it.
    KillAtDispatch,
    /// A backend dies after the scatter, losing its in-flight
    /// sub-response.
    KillAtGather,
    /// A completed sub-response is dropped and must be re-executed.
    DroppedCompletion,
    /// A stage completes late (sleep); results must not change.
    DelayedStage,
}

impl Scenario {
    /// All injectable scenarios, in a fixed order (the chaos suite
    /// iterates this).
    pub const ALL: [Scenario; 4] = [
        Scenario::KillAtDispatch,
        Scenario::KillAtGather,
        Scenario::DroppedCompletion,
        Scenario::DelayedStage,
    ];

    /// Short name for logs and failure messages.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::KillAtDispatch => "kill-at-dispatch",
            Scenario::KillAtGather => "kill-at-gather",
            Scenario::DroppedCompletion => "dropped-completion",
            Scenario::DelayedStage => "delayed-stage",
        }
    }
}

impl FaultPlan {
    /// An empty plan (injects nothing). `seed` is carried for
    /// reporting; use the builder methods to add faults.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// The seed this plan reports (and, for [`FaultPlan::random`], was
    /// generated from).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Add a dispatch-stage fault for facade ticket `ticket`.
    pub fn on_dispatch(mut self, ticket: u64, fault: Fault) -> FaultPlan {
        self.dispatch.entry(ticket).or_default().push(fault);
        self
    }

    /// Add a gather-stage fault for facade ticket `ticket`.
    pub fn on_gather(mut self, ticket: u64, fault: Fault) -> FaultPlan {
        self.gather.entry(ticket).or_default().push(fault);
        self
    }

    /// A one-fault plan for the named scenario: ticket `ticket`, shard
    /// `shard` (delays hit the dispatch stage of the same ticket).
    pub fn scenario(seed: u64, s: Scenario, ticket: u64, shard: usize) -> FaultPlan {
        let plan = FaultPlan::new(seed);
        match s {
            Scenario::KillAtDispatch => plan.on_dispatch(ticket, Fault::KillShard { shard }),
            Scenario::KillAtGather => plan.on_gather(ticket, Fault::KillShard { shard }),
            Scenario::DroppedCompletion => {
                plan.on_gather(ticket, Fault::DropCompletion { shard })
            }
            Scenario::DelayedStage => plan.on_dispatch(ticket, Fault::Delay { millis: 5 }),
        }
    }

    /// A seed-reproducible random schedule over tickets `1..=tickets`:
    /// each ticket independently draws one fault with probability
    /// `p_fault` — scenario and target shard uniform from
    /// [`Scenario::ALL`] and `0..shards`. Identical `(seed, tickets,
    /// shards, p_fault)` always builds an identical plan (locked by a
    /// unit test), so any failing chaos run reproduces from its printed
    /// seed alone.
    pub fn random(seed: u64, tickets: u64, shards: usize, p_fault: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xC0A5_7E57_F417_7B1A);
        let mut plan = FaultPlan::new(seed);
        for ticket in 1..=tickets {
            if !rng.gen_bool(p_fault) {
                continue;
            }
            let shard = rng.gen_range(shards.max(1));
            plan = match Scenario::ALL[rng.gen_range(Scenario::ALL.len())] {
                Scenario::KillAtDispatch => {
                    plan.on_dispatch(ticket, Fault::KillShard { shard })
                }
                Scenario::KillAtGather => plan.on_gather(ticket, Fault::KillShard { shard }),
                Scenario::DroppedCompletion => {
                    plan.on_gather(ticket, Fault::DropCompletion { shard })
                }
                Scenario::DelayedStage => {
                    plan.on_dispatch(ticket, Fault::Delay { millis: 1 + rng.gen_range(3) as u64 })
                }
            };
        }
        plan
    }

    /// Total faults scheduled across both stages.
    pub fn len(&self) -> usize {
        self.dispatch.values().map(Vec::len).sum::<usize>()
            + self.gather.values().map(Vec::len).sum::<usize>()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.dispatch.is_empty() && self.gather.is_empty()
    }
}

impl FaultInjector for FaultPlan {
    fn at_dispatch(&self, ticket: u64) -> Vec<Fault> {
        self.dispatch.get(&ticket).cloned().unwrap_or_default()
    }

    fn at_gather(&self, ticket: u64) -> Vec<Fault> {
        self.gather.get(&ticket).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_builder_routes_faults_to_the_right_stage() {
        let plan = FaultPlan::new(42)
            .on_dispatch(3, Fault::KillShard { shard: 1 })
            .on_dispatch(3, Fault::Delay { millis: 2 })
            .on_gather(5, Fault::DropCompletion { shard: 0 });
        assert_eq!(plan.seed(), 42);
        assert_eq!(plan.len(), 3);
        assert!(!plan.is_empty());
        assert_eq!(
            plan.at_dispatch(3),
            vec![Fault::KillShard { shard: 1 }, Fault::Delay { millis: 2 }]
        );
        assert_eq!(plan.at_gather(3), vec![]);
        assert_eq!(plan.at_gather(5), vec![Fault::DropCompletion { shard: 0 }]);
        assert_eq!(plan.at_dispatch(99), vec![]);
        assert!(FaultPlan::new(0).is_empty());
    }

    #[test]
    fn random_plans_are_seed_reproducible() {
        // The one-command-reproduction guarantee: identical inputs must
        // build identical schedules, different seeds almost surely not.
        let a = FaultPlan::random(0xDEAD_BEEF, 64, 5, 0.5);
        let b = FaultPlan::random(0xDEAD_BEEF, 64, 5, 0.5);
        assert_eq!(a, b, "same seed must reproduce the exact schedule");
        assert!(!a.is_empty(), "p=0.5 over 64 tickets injects something");
        let c = FaultPlan::random(0xDEAD_BEEF + 1, 64, 5, 0.5);
        assert_ne!(a, c, "a different seed must draw a different schedule");
        // p=1 faults every ticket exactly once; p=0 faults none.
        assert_eq!(FaultPlan::random(7, 10, 3, 1.0).len(), 10);
        assert!(FaultPlan::random(7, 10, 3, 0.0).is_empty());
    }

    #[test]
    fn scenario_constructors_cover_the_taxonomy() {
        for s in Scenario::ALL {
            let plan = FaultPlan::scenario(9, s, 2, 1);
            assert_eq!(plan.len(), 1, "{}", s.name());
            let injected = [plan.at_dispatch(2), plan.at_gather(2)].concat();
            assert_eq!(injected.len(), 1);
            // Display names are stable (failure messages key on them).
            assert!(!format!("{}", injected[0]).is_empty());
        }
        assert_eq!(Scenario::KillAtGather.name(), "kill-at-gather");
    }
}
