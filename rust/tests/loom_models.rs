//! Exhaustive concurrency models (loom) for the four hottest protocols
//! in the serving tier, plus the shard respawn race and the scheduler
//! pause/resume protocol.
//!
//! Compiled only under `--cfg loom` (a plain `cargo test` sees an empty
//! binary and needs no `loom` dependency). Run via `scripts/analyze.sh`,
//! which temporarily injects the loom dependency and sets
//! `RUSTFLAGS="--cfg loom"`; or by hand:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Every model body lives in `sparsep::coordinator::verify` (so it can
//! drive the real `pub(crate)` machinery) or uses public facade types
//! directly. Models are scaled down — ≤ 3 threads, 2-element waves —
//! because loom explores every interleaving; the protocols themselves
//! are the production code paths, reached through the
//! `sparsep::util::sync` facade the whole crate is built on.

#![cfg(loom)]

use sparsep::coordinator::verify;
use sparsep::util::sync::atomic::{AtomicUsize, Ordering};
use sparsep::util::sync::{thread, Arc, ReduceSlot, RespawnSlot};

/// Bounded-exhaustive exploration: preemption bounding (3) keeps the
/// deeper models tractable while still covering every interleaving
/// that at most 3 forced preemptions can reach — the standard loom
/// configuration for condvar-heavy protocols.
fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

#[test]
fn pool_wave_protocol_runs_every_index_exactly_once() {
    model(|| verify::pool_wave_round(2, 2));
}

#[test]
fn pool_wave_single_worker_with_wide_wave() {
    model(|| verify::pool_wave_round(1, 3));
}

#[test]
fn pool_task_panic_reraises_on_submitter_and_spares_workers() {
    model(verify::pool_panic_round);
}

#[test]
fn completions_wait_timeout_never_loses_a_racing_publish() {
    model(verify::completions_claim_round);
}

#[test]
fn buffer_pool_recycle_handoff_is_race_free() {
    model(verify::buffer_pool_recycle_round);
}

#[test]
fn respawn_slot_rebuilds_exactly_once_under_racing_respawners() {
    model(|| {
        // The shard dead-flag protocol (`Backends::ensure_alive`): two
        // threads race to respawn one killed backend. Exactly one may
        // rebuild (the double-checked write-lock protocol), exactly one
        // may report having respawned, and the slot must end alive.
        let slot: Arc<RespawnSlot<u32>> = Arc::new(RespawnSlot::new(0));
        slot.kill();
        let rebuilds = Arc::new(AtomicUsize::new(0));
        let respawn_credits = Arc::new(AtomicUsize::new(0));

        let racer = {
            let (slot, rebuilds, credits) =
                (Arc::clone(&slot), Arc::clone(&rebuilds), Arc::clone(&respawn_credits));
            thread::spawn_named("respawn-racer", move || {
                let did = slot
                    .ensure_alive(|s: &mut u32| {
                        rebuilds.fetch_add(1, Ordering::SeqCst);
                        *s += 1;
                        Ok::<(), ()>(())
                    })
                    .expect("rebuild cannot fail here");
                if did {
                    credits.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let did = slot
            .ensure_alive(|s: &mut u32| {
                rebuilds.fetch_add(1, Ordering::SeqCst);
                *s += 1;
                Ok::<(), ()>(())
            })
            .expect("rebuild cannot fail here");
        if did {
            respawn_credits.fetch_add(1, Ordering::SeqCst);
        }
        racer.join().expect("racing respawner panicked");

        assert_eq!(rebuilds.load(Ordering::SeqCst), 1, "exactly one rebuild may run");
        assert_eq!(
            respawn_credits.load(Ordering::SeqCst),
            1,
            "exactly one caller may count the respawn"
        );
        assert!(!slot.is_dead(), "slot must end alive");
        assert_eq!(*slot.read(), 1, "the single rebuild's effect must be visible");
    });
}

#[test]
fn scheduler_pause_resume_with_full_tenant_queue_never_deadlocks() {
    model(verify::scheduler_pause_resume_round);
}

#[test]
fn reduce_slot_collects_every_partial_exactly_once_in_index_order() {
    model(|| {
        // The reduction-gather rendezvous (`merge_grid_runs`'s
        // per-band accumulation): two column stripes publish their
        // partials from racing threads, out of index order, while the
        // gather thread waits for the full set. `wait_all` must block
        // until both are in and hand the partials back in index order —
        // the fixed ascending-column reduction the bit-reproducibility
        // contract depends on — no matter the publish interleaving.
        let slot: Arc<ReduceSlot<u32>> = Arc::new(ReduceSlot::new(2));
        let publishers: Vec<_> = [(1usize, 11u32), (0usize, 10u32)]
            .into_iter()
            .map(|(idx, part)| {
                let slot = Arc::clone(&slot);
                thread::spawn_named("reduce-publisher", move || {
                    assert!(slot.publish(idx, part), "first publish at {idx} must be fresh");
                })
            })
            .collect();
        let parts = slot.wait_all();
        assert_eq!(parts, vec![10, 11], "partials must come back in column-index order");
        for p in publishers {
            p.join().expect("reduce publisher panicked");
        }
    });
}

#[test]
fn reduce_slot_racing_duplicate_publishes_store_exactly_once() {
    model(|| {
        // Recovery can re-publish a stripe's partial (a re-executed
        // sub-request racing the original completion). Exactly one of
        // two racing publishes at the same index may win; the loser is
        // told so, and the winner's value is what `wait_all` returns.
        let slot: Arc<ReduceSlot<u32>> = Arc::new(ReduceSlot::new(2));
        let fresh = Arc::new(AtomicUsize::new(0));
        let racers: Vec<_> = (0..2)
            .map(|_| {
                let (slot, fresh) = (Arc::clone(&slot), Arc::clone(&fresh));
                thread::spawn_named("reduce-duplicator", move || {
                    if slot.publish(0, 7) {
                        fresh.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for r in racers {
            r.join().expect("racing duplicate publisher panicked");
        }
        assert_eq!(fresh.load(Ordering::SeqCst), 1, "exactly one duplicate may be fresh");
        assert!(slot.publish(1, 99), "the other stripe's first publish is fresh");
        assert_eq!(slot.wait_all(), vec![7, 99], "the winning duplicate's value must stand");
    });
}
