//! Property tests for shard planning and the scatter/gather layer
//! (hand-rolled; proptest is not in the offline vendor set): for random
//! COO matrices and shard counts,
//!
//! * the planned shard row-ranges tile `[0, nrows)` contiguously with
//!   no empty shard (effective count `min(shards, nrows)`), so every
//!   row — and therefore every stored non-zero — lands in exactly one
//!   shard;
//! * slicing the matrix by those ranges partitions the non-zeros
//!   exactly (counts and triples add back up);
//! * gathering a `ShardedService`'s per-shard outputs reconstructs the
//!   host-oracle SpMV bit-exactly.

//! * killing a random shard backend respawns it from the shared plan
//!   cache (no plan-build leak) and the post-recovery gather still
//!   equals the oracle;
//! * 2D grids: every stored non-zero lands in exactly one `(row band,
//!   column stripe)` tile, the reduced gather still reconstructs the
//!   oracle bit-exactly over random grid shapes and replica counts, and
//!   killing a random replica slot during flight recovers without
//!   building a single new plan.

use sparsep::coordinator::{
    plan_shards, Fault, FaultPlan, GridSpec, KernelSpec, Request, ShardedService,
    ShardedServiceBuilder,
};
use sparsep::matrix::CooMatrix;
use sparsep::pim::PimSystem;
use sparsep::util::rng::Rng;
use std::sync::Arc;

/// Random sparse matrix with rng-chosen shape and density (integer
/// values: sums are exact in f64, so bit-equality with the host oracle
/// is meaningful).
fn random_matrix(rng: &mut Rng) -> CooMatrix<f64> {
    let nrows = 1 + rng.gen_range(200);
    let ncols = 1 + rng.gen_range(200);
    let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
    let mut triples = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        triples.push((
            rng.gen_range(nrows) as u32,
            rng.gen_range(ncols) as u32,
            (rng.gen_range(9) as f64) - 4.0,
        ));
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

/// PROPERTY: shard ranges tile the row space, never empty, and
/// partition the non-zeros exactly.
#[test]
fn prop_shard_ranges_tile_rows_and_nnz() {
    let mut rng = Rng::new(0x5AADED);
    for trial in 0..200 {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(12);
        let ranges = plan_shards(&m, shards);
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards}",
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        assert_eq!(ranges.len(), shards.min(m.nrows()).max(1), "{tag}: shard count");
        assert_eq!(ranges[0].start, 0, "{tag}: first range must start at row 0");
        assert_eq!(ranges.last().unwrap().end, m.nrows(), "{tag}: last range must end at nrows");
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{tag}: ranges must tile contiguously");
        }
        if m.nrows() > 0 {
            assert!(ranges.iter().all(|r| !r.is_empty()), "{tag}: empty shard range");
        }
        // Row/nnz partition: slicing by the ranges recovers every
        // non-zero exactly once, in order.
        let mut sliced_nnz = 0usize;
        let mut gathered: Vec<(u32, u32, f64)> = Vec::with_capacity(m.nnz());
        for r in &ranges {
            let slice = m.row_range_slice(r.start, r.end);
            assert_eq!(slice.nrows(), r.len(), "{tag}: slice row count");
            assert_eq!(slice.ncols(), m.ncols(), "{tag}: slices keep the column space");
            sliced_nnz += slice.nnz();
            gathered.extend(
                slice.iter().map(|(row, col, v)| (row + r.start as u32, col, v)),
            );
        }
        assert_eq!(sliced_nnz, m.nnz(), "{tag}: non-zeros must partition exactly");
        let original: Vec<(u32, u32, f64)> = m.iter().collect();
        assert_eq!(gathered, original, "{tag}: gathered triples must reconstruct the matrix");
    }
}

/// PROPERTY: shard-count balance — nnz-weighted planning never gives a
/// shard more non-zeros than one row short of the whole matrix, and on
/// matrices with spread-out rows the heaviest shard is within a row of
/// the greedy balanced cut (sanity envelope, not a tight bound).
#[test]
fn prop_shard_planning_balances_nnz() {
    let mut rng = Rng::new(0xBA1A2CE);
    for _ in 0..100 {
        let m = random_matrix(&mut rng);
        let shards = 2 + rng.gen_range(6);
        let ranges = plan_shards(&m, shards);
        let counts = m.row_counts();
        let per_shard: Vec<usize> =
            ranges.iter().map(|r| counts[r.clone()].iter().sum()).collect();
        let total: usize = per_shard.iter().sum();
        assert_eq!(total, m.nnz());
        let max_row = counts.iter().copied().max().unwrap_or(0);
        let ideal = m.nnz().div_ceil(ranges.len());
        let heaviest = per_shard.iter().copied().max().unwrap_or(0);
        // Loose envelope: greedy row-granular splitting underfills each
        // chunk by < one row, and the shortfall compounds harmonically
        // into the tail chunk — 3x the heaviest row safely covers every
        // shard count the suite uses. The point is "roughly balanced",
        // not "one shard takes all".
        assert!(
            heaviest <= ideal + 3 * max_row,
            "heaviest shard {heaviest} exceeds ideal {ideal} + 3 * max row {max_row} ({}x{} nnz={} shards={})",
            m.nrows(),
            m.ncols(),
            m.nnz(),
            ranges.len()
        );
    }
}

/// PROPERTY: gather reconstructs the host oracle bit-exactly for random
/// matrices, shard counts and kernels — spmv, batch and iterate.
#[test]
fn prop_sharded_gather_reconstructs_oracle() {
    let mut rng = Rng::new(0xC0DE5A);
    let kernels =
        [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::coo_row(), KernelSpec::bcoo_nnz()];
    for trial in 0..25usize {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(6);
        let spec = &kernels[rng.gen_range(kernels.len())];
        let n_dpus = 1 + rng.gen_range(12);
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards} dpus={n_dpus} {}",
            m.nrows(),
            m.ncols(),
            m.nnz(),
            spec.name
        );
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .build(PimSystem::with_dpus(n_dpus))
            .unwrap();
        let h = svc.load(&m, spec).unwrap();
        let x: Vec<f64> =
            (0..m.ncols()).map(|i| ((i * 7 + trial) % 11) as f64 - 5.0).collect();
        let r = svc.spmv(&h, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x), "{tag}: spmv");
        assert_eq!(r.stats.nnz, m.nnz(), "{tag}: merged nnz");
        let xs: Vec<Vec<f64>> = (0..3usize)
            .map(|b| (0..m.ncols()).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect())
            .collect();
        let batch = svc.spmv_batch(&h, &xs).unwrap();
        for (x, run) in xs.iter().zip(&batch.runs) {
            assert_eq!(run.y, m.spmv(x), "{tag}: batch");
        }
        if m.nrows() == m.ncols() {
            let it = svc.iterate(&h, &x, 3).unwrap();
            let mut want = x.clone();
            for _ in 0..3 {
                want = m.spmv(&want);
            }
            assert_eq!(it.last.y, want, "{tag}: iterate");
        }
    }
}

/// PROPERTY: kill-one-shard-and-recover — for random matrices and a
/// random target shard killed at the first ticket's dispatch, the
/// backend respawns from the shared plan cache (exactly one respawn,
/// zero new plan builds — the cache already holds every slice's plan),
/// the post-recovery gather is bit-identical to the host oracle, and
/// the facade stays fully serviceable.
#[test]
fn prop_killed_shard_recovers_bit_exactly() {
    let mut rng = Rng::new(0xDEAD_BEA7);
    for trial in 0..20usize {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(5);
        // Matrices with fewer rows than shards use fewer shards: aim
        // the kill at a shard that actually exists.
        let effective = plan_shards(&m, shards).len();
        let target = rng.gen_range(effective);
        let seed = 0x5EED ^ trial as u64;
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards} effective={effective} target={target} seed={seed:#x}",
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        let plan = FaultPlan::new(seed).on_dispatch(1, Fault::KillShard { shard: target });
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .fault_injector(Arc::new(plan))
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        assert_eq!(svc.shard_ranges(&h).unwrap().len(), effective, "{tag}: effective shards");
        let builds_before = svc.stats().plan_builds;
        let x: Vec<f64> =
            (0..m.ncols()).map(|i| ((i * 5 + trial) % 13) as f64 - 6.0).collect();
        let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let run = svc.wait(t).unwrap().into_spmv().unwrap();
        assert_eq!(run.y, m.spmv(&x), "{tag}: post-recovery gather vs oracle");
        let st = svc.stats();
        assert_eq!(st.respawns, 1, "{tag}: exactly one respawn");
        assert_eq!(
            st.plan_builds, builds_before,
            "{tag}: respawn must re-load through cache hits, never leak plan builds"
        );
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x), "{tag}: facade after recovery");
    }
}

/// PROPERTY: 2D tile planning partitions the matrix — band-major tile
/// rectangles cover `[0, nrows) x [0, ncols)` with each band's stripes
/// tiling the column space contiguously, and every stored non-zero
/// falls inside exactly one tile.
#[test]
fn prop_grid_tiles_partition_rows_columns_and_nnz() {
    let mut rng = Rng::new(0x6B1D);
    for trial in 0..60usize {
        let m = random_matrix(&mut rng);
        let rows = 1 + rng.gen_range(5);
        let cols = 1 + rng.gen_range(4);
        let tag = format!(
            "trial {trial}: {}x{} nnz={} grid={rows}x{cols}",
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .grid(rows, cols)
            .build(PimSystem::with_dpus(2))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let tiles = svc.tile_ranges(&h).unwrap();
        // Effective bands/stripes never exceed the configured shape or
        // the matrix dimensions, and the tile list is band-major.
        let bands = tiles.iter().map(|(r, _)| r.clone()).collect::<Vec<_>>();
        let n_bands = 1 + bands.windows(2).filter(|w| w[0] != w[1]).count();
        let cols_eff = tiles.len() / n_bands;
        assert_eq!(cols_eff * n_bands, tiles.len(), "{tag}: ragged tile list");
        assert_eq!(bands[0].start, 0, "{tag}: first band starts at row 0");
        assert_eq!(bands.last().unwrap().end, m.nrows(), "{tag}: last band ends at nrows");
        for band in tiles.chunks(cols_eff) {
            assert!(
                band.iter().all(|(r, _)| *r == band[0].0),
                "{tag}: a band's stripes must share its row range"
            );
            assert_eq!(band[0].1.start, 0, "{tag}: first stripe starts at col 0");
            assert_eq!(band.last().unwrap().1.end, m.ncols(), "{tag}: last stripe ends at ncols");
            for w in band.windows(2) {
                assert_eq!(w[0].1.end, w[1].1.start, "{tag}: stripes must tile contiguously");
            }
            if m.ncols() > 0 {
                assert!(band.iter().all(|(_, c)| !c.is_empty()), "{tag}: empty column stripe");
            }
        }
        // Exactly-once coverage: each stored non-zero is inside one and
        // only one tile rectangle.
        for (row, col, _) in m.iter() {
            let owners = tiles
                .iter()
                .filter(|(r, c)| {
                    r.contains(&(row as usize)) && c.contains(&(col as usize))
                })
                .count();
            assert_eq!(owners, 1, "{tag}: non-zero ({row},{col}) owned by {owners} tiles");
        }
    }
}

/// PROPERTY: the reduced gather reconstructs the host oracle bit-exactly
/// over random matrices, grid shapes and replica counts — spmv, batch,
/// and iterate (square matrices).
#[test]
fn prop_reduced_gather_matches_oracle_over_random_grids() {
    let mut rng = Rng::new(0x92D6A7);
    let kernels = [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::coo_row()];
    for trial in 0..20usize {
        let m = random_matrix(&mut rng);
        let rows = 1 + rng.gen_range(4);
        let cols = 1 + rng.gen_range(3);
        let replicas = 1 + rng.gen_range(2);
        let spec = &kernels[rng.gen_range(kernels.len())];
        let tag = format!(
            "trial {trial}: {}x{} nnz={} grid={rows}x{cols} K={replicas} {}",
            m.nrows(),
            m.ncols(),
            m.nnz(),
            spec.name
        );
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .grid(rows, cols)
            .replicas(replicas)
            .build(PimSystem::with_dpus(3))
            .unwrap();
        assert_eq!(
            svc.grid(),
            GridSpec { rows, cols, replicas },
            "{tag}: configured topology"
        );
        let h = svc.load(&m, spec).unwrap();
        let x: Vec<f64> =
            (0..m.ncols()).map(|i| ((i * 3 + trial) % 11) as f64 - 5.0).collect();
        let r = svc.spmv(&h, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x), "{tag}: reduced spmv vs oracle");
        assert_eq!(r.stats.nnz, m.nnz(), "{tag}: merged nnz accounts every entry once");
        let xs: Vec<Vec<f64>> = (0..2usize)
            .map(|b| (0..m.ncols()).map(|i| ((i + 5 * b) % 7) as f64 - 3.0).collect())
            .collect();
        let batch = svc.spmv_batch(&h, &xs).unwrap();
        for (x, run) in xs.iter().zip(&batch.runs) {
            assert_eq!(run.y, m.spmv(x), "{tag}: reduced batch vs oracle");
        }
        if m.nrows() == m.ncols() {
            let it = svc.iterate(&h, &x, 2).unwrap();
            let want = m.spmv(&m.spmv(&x));
            assert_eq!(it.last.y, want, "{tag}: reduced iterate vs oracle");
        }
    }
}

/// PROPERTY: killing a random replica slot while a request is in flight
/// recovers bit-exactly and never builds a new plan — replicas serve
/// from the tile's cached plan, and a forced re-load (which
/// ensure-alives every slot) is a pure cache hit too.
#[test]
fn prop_replica_kill_during_flight_recovers_with_flat_builds() {
    let mut rng = Rng::new(0x4E_9B11);
    for trial in 0..15usize {
        // Keep the matrix at least as large as the widest grid so the
        // effective grid equals the configured one and every slot is
        // reachable by the fault key.
        let nrows = 8 + rng.gen_range(150);
        let ncols = 8 + rng.gen_range(150);
        let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
        let triples: Vec<(u32, u32, f64)> = (0..nnz)
            .map(|_| {
                (
                    rng.gen_range(nrows) as u32,
                    rng.gen_range(ncols) as u32,
                    (rng.gen_range(9) as f64) - 4.0,
                )
            })
            .collect();
        let m = CooMatrix::from_triples(nrows, ncols, triples);
        let rows = 1 + rng.gen_range(3);
        let cols = 1 + rng.gen_range(3);
        let replicas = 2;
        let slots = rows * cols * replicas;
        let target = rng.gen_range(slots);
        let seed = 0x9E6D ^ trial as u64;
        let tag = format!(
            "trial {trial}: {nrows}x{ncols} nnz={} grid={rows}x{cols} K={replicas} target={target} seed={seed:#x}",
            m.nnz()
        );
        let plan = FaultPlan::new(seed).on_dispatch(1, Fault::KillShard { shard: target });
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .grid(rows, cols)
            .replicas(replicas)
            .fault_injector(Arc::new(plan))
            .build(PimSystem::with_dpus(2))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let builds_before = svc.stats().plan_builds;
        let x: Vec<f64> =
            (0..ncols).map(|i| ((i * 7 + trial) % 13) as f64 - 6.0).collect();
        let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let run = svc.wait(t).unwrap().into_spmv().unwrap();
        assert_eq!(run.y, m.spmv(&x), "{tag}: post-kill gather vs oracle");
        // Force the respawn deterministically (reads only touch the
        // dead slot if least-outstanding picks it): re-loading the same
        // matrix ensure-alives every slot and hits the plan cache.
        let _h2 = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let st = svc.stats();
        assert!(st.respawns >= 1, "{tag}: the killed slot must respawn");
        assert_eq!(
            st.plan_builds, builds_before,
            "{tag}: replica recovery and re-loads must be pure cache hits"
        );
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x), "{tag}: facade after recovery");
    }
}
