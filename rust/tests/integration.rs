//! Integration tests: the public API exercised end to end, across
//! formats, partitionings, data types and system shapes.

// These suites deliberately exercise `SpmvExecutor`'s deprecated
// compatibility wrappers (`execute` / `execute_batch` / `run_iterations`
// / `run_iterations_batch` / `run`): they lock the wrappers' behavior
// until a future major removal. New code routes through
// `coordinator::SpmvService` or `ExecutionPlan::{execute, ...}`.
#![allow(deprecated)]

use sparsep::coordinator::{KernelSpec, SpmvExecutor};
use sparsep::matrix::{generate, mtx, CooMatrix, CsrMatrix, Format};
use sparsep::pim::{PimConfig, PimSystem};

fn x_for(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 23) as f64) - 11.0).collect()
}

#[test]
fn all_25_kernels_exact_on_every_suite_class() {
    for e in generate::mini_suite() {
        let m = (e.gen)(101);
        let x = x_for(m.ncols());
        let gold = m.spmv(&x);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(32));
        for spec in KernelSpec::all25(4) {
            let r = exec.run(&spec, &m, &x).unwrap();
            assert_eq!(r.y, gold, "{}/{}", e.name, spec.name);
        }
    }
}

#[test]
fn exactness_holds_across_system_shapes() {
    let m = generate::scale_free::<f64>(777, 777, 7, 0.6, 5);
    let x = x_for(777);
    let gold = m.spmv(&x);
    for n_dpus in [1usize, 3, 64, 257] {
        for tasklets in [1usize, 12, 24] {
            let exec = SpmvExecutor::new(PimSystem {
                cfg: PimConfig { n_dpus, tasklets, ..Default::default() },
            });
            for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::bcoo_block()] {
                let r = exec.run(&spec, &m, &x).unwrap();
                assert_eq!(r.y, gold, "{} d={n_dpus} t={tasklets}", spec.name);
            }
        }
    }
}

#[test]
fn two_d_stripe_counts_stay_exact() {
    let m = generate::uniform::<f64>(400, 400, 9, 3);
    let x = x_for(400);
    let gold = m.spmv(&x);
    let exec = SpmvExecutor::new(PimSystem::with_dpus(64));
    for fmt in Format::all() {
        for stripes in [1usize, 2, 8, 16, 32, 64] {
            let spec = KernelSpec::two_d_balanced(fmt, stripes);
            let r = exec.run(&spec, &m, &x).unwrap();
            assert_eq!(r.y, gold, "{} stripes={stripes}", spec.name);
        }
    }
}

#[test]
fn mtx_file_roundtrip_through_executor() {
    let m = generate::scale_free::<f64>(300, 300, 6, 0.5, 9);
    let dir = std::env::temp_dir().join("sparsep_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.mtx");
    mtx::write_mtx(&m, &path).unwrap();
    let back: CooMatrix<f64> = mtx::read_mtx(&path).unwrap();
    assert_eq!(m, back);
    let x = x_for(300);
    let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
    let r = exec.run(&KernelSpec::coo_nnz(), &back, &x).unwrap();
    assert_eq!(r.y, m.spmv(&x));
}

#[test]
fn dtype_cross_check_against_f64() {
    // Integer kernels computed in the simulator must equal the integer
    // host oracle, which (for small values) equals the f64 result.
    let m64 = generate::uniform::<f64>(256, 256, 8, 17);
    let x32: Vec<i32> = (0..256).map(|i| (i % 5) as i32 - 2).collect();
    let x64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
    let mi: CooMatrix<i32> = m64.cast();
    let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
    let ri = exec.run(&KernelSpec::coo_nnz(), &mi, &x32).unwrap();
    let rf = exec.run(&KernelSpec::coo_nnz(), &m64, &x64).unwrap();
    for (a, b) in ri.y.iter().zip(&rf.y) {
        assert_eq!(*a as f64, *b);
    }
}

#[test]
fn broadcast_wall_and_2d_rescue() {
    // The paper's core end-to-end story as one assertion chain.
    let m = generate::uniform::<f64>(8192, 8192, 16, 3);
    let x = x_for(8192);
    let run = |spec: &KernelSpec, d: usize| {
        SpmvExecutor::new(PimSystem::with_dpus(d)).run(spec, &m, &x).unwrap()
    };
    // Kernel-only 1D scales.
    let k64 = run(&KernelSpec::coo_nnz(), 64).breakdown.kernel_s;
    let k1024 = run(&KernelSpec::coo_nnz(), 1024).breakdown.kernel_s;
    // Sub-linear (per-DPU fixed costs bite at 128 nnz/DPU) but clearly
    // scaling — the paper's kernel-only curves are sub-linear too.
    assert!(k1024 < k64 / 2.5, "kernel should scale: {k64} -> {k1024}");
    // End-to-end 1D does not (broadcast wall).
    let t64 = run(&KernelSpec::coo_nnz(), 64).breakdown.total_s();
    let t1024 = run(&KernelSpec::coo_nnz(), 1024).breakdown.total_s();
    assert!(t1024 > t64 / 4.0, "broadcast should prevent linear e2e scaling");
    // 2D loads less at high DPU counts.
    let one = run(&KernelSpec::coo_nnz(), 1024);
    let two = run(&KernelSpec::two_d_equally_wide(Format::Coo, 16), 1024);
    assert!(two.breakdown.load_s < one.breakdown.load_s);
    // ...and pays in retrieve+merge.
    assert!(two.breakdown.retrieve_s + two.breakdown.merge_s > one.breakdown.retrieve_s);
}

#[test]
fn energy_orderings() {
    let m = generate::uniform::<f64>(2048, 2048, 8, 7);
    let x = x_for(2048);
    let e = |d: usize| {
        SpmvExecutor::new(PimSystem::with_dpus(d))
            .run(&KernelSpec::coo_nnz_rgrn(), &m, &x)
            .unwrap()
            .energy
    };
    let e64 = e(64);
    let e1024 = e(1024);
    // More DPUs move more broadcast bytes => more bus energy.
    assert!(e1024.bus_j > e64.bus_j);
    assert!(e64.total_j() > 0.0);
}

#[test]
fn empty_and_degenerate_matrices() {
    let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
    // Empty matrix.
    let m = CooMatrix::<f64>::zeros(64, 64);
    let r = exec.run(&KernelSpec::coo_nnz(), &m, &vec![1.0; 64]).unwrap();
    assert_eq!(r.y, vec![0.0; 64]);
    // Single element.
    let m1 = CooMatrix::from_triples(64, 64, vec![(63, 0, 2.5f64)]);
    let r1 = exec.run(&KernelSpec::csr_nnz(), &m1, &vec![2.0; 64]).unwrap();
    assert_eq!(r1.y[63], 5.0);
    // Single row spanning all DPUs (element-granularity split).
    let wide =
        CooMatrix::from_triples(1, 512, (0..512u32).map(|c| (0, c, 1.0f64)).collect());
    let rw = exec.run(&KernelSpec::coo_nnz(), &wide, &vec![1.0; 512]).unwrap();
    assert_eq!(rw.y, vec![512.0]);
}

#[test]
fn csr_matches_coo_through_all_public_paths() {
    let m = generate::scale_free::<f64>(500, 400, 8, 0.7, 13);
    let csr = CsrMatrix::from_coo(&m);
    let x = x_for(400);
    assert_eq!(csr.spmv(&x), m.spmv(&x));
    let back = csr.to_coo();
    assert_eq!(back.spmv(&x), m.spmv(&x));
}
