//! BCOO DPU kernel.
//!
//! The block analogue of the COO kernel: every stored block carries both
//! block-row and block-column indices, so block-granularity splits are
//! natural (`BCOO.block`) and nnz-balanced splits can cut anywhere in the
//! block stream (`BCOO.nnz`). Shared block rows synchronize like COO's
//! shared rows.

use super::{acct, DpuKernelOutput, SyncScheme, TaskletBalance};
use crate::matrix::{BcooMatrix, SpElem};
use crate::partition::balance::split_elements;
use crate::pim::{calib, PimConfig, TaskletCounters};

/// Plan-time per-tasklet split for the BCOO kernel: block ranges plus
/// shared-block-row metadata — computed identically for the
/// single-vector and batched entry points so the two walks (and their
/// accounting) can never drift apart, and cached per work item by the
/// execution plan.
#[derive(Clone, Debug)]
pub struct BcooSplit {
    /// Tasklet count the split was computed for.
    pub(crate) tasklets: usize,
    ranges: Vec<std::ops::Range<usize>>,
    shares_rows: bool,
    /// Distinct shared block rows (lock-free merge epilogue size).
    n_shared: usize,
    /// Per tasklet: (head block row shared with the previous range,
    /// tail shared with the next), `u32::MAX` when unshared.
    shared_bounds: Vec<(u32, u32)>,
}

/// Compute the per-tasklet block split (see [`BcooSplit`]).
pub fn bcoo_split<T: SpElem>(slice: &BcooMatrix<T>, t: usize, bal: TaskletBalance) -> BcooSplit {
    let nblocks = slice.nblocks();
    let mut ranges = split_elements(nblocks, t);
    let mut shares_rows = true;
    if bal == TaskletBalance::Rows {
        // Snap each boundary forward to the next block-row transition so
        // no block row is shared (lock-free).
        shares_rows = false;
        for i in 0..ranges.len() - 1 {
            let mut e = ranges[i].end;
            while e > ranges[i].start
                && e < nblocks
                && slice.block_rows[e] == slice.block_rows[e - 1]
            {
                e += 1;
                if e == nblocks {
                    break;
                }
            }
            let e = e.min(nblocks);
            ranges[i].end = e;
            ranges[i + 1].start = e.max(ranges[i + 1].start.min(nblocks)).max(e);
            ranges[i + 1].end = ranges[i + 1].end.max(ranges[i + 1].start);
        }
        if let Some(last) = ranges.last_mut() {
            last.end = nblocks;
        }
    }

    // Shared block rows live only at range boundaries (blocks sorted by
    // block row): two compares per block instead of a hash probe.
    let mut n_shared = 0usize;
    let mut shared_bounds: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); t];
    if shares_rows {
        let mut last_shared = u32::MAX;
        for i in 0..ranges.len().saturating_sub(1) {
            let (a, b) = (&ranges[i], &ranges[i + 1]);
            if !a.is_empty() && !b.is_empty() && a.end < nblocks {
                let row = slice.block_rows[a.end - 1];
                if row == slice.block_rows[b.start] {
                    if row != last_shared {
                        n_shared += 1;
                        last_shared = row;
                    }
                    shared_bounds[i].1 = row;
                    shared_bounds[i + 1].0 = row;
                }
            }
        }
    }
    BcooSplit { tasklets: t, ranges, shares_rows, n_shared, shared_bounds }
}

/// Run the BCOO kernel on one DPU.
///
/// All balancing schemes reduce to a contiguous block-range split (BCOO
/// blocks all have equal weight `br*bc`, so `Blocks`, `Nnz` and
/// `NnzElement` coincide; `Rows` additionally snaps range boundaries to
/// block-row transitions, making it lock-free).
pub fn run_bcoo_dpu<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcooMatrix<T>,
    x: &[T],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    run_bcoo_dpu_cached(cfg, slice, x, &bcoo_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_bcoo_dpu`] with a precomputed [`BcooSplit`] — the
/// plan-time-split entry point (the execution plan caches one split per
/// work item). `split` must have been computed for `cfg.tasklets`
/// tasklets.
pub fn run_bcoo_dpu_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcooMatrix<T>,
    x: &[T],
    split: &BcooSplit,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let (br, bc) = (slice.br, slice.bc);
    let mut y = vec![T::zero(); slice.nrows()];
    let mut counters = vec![TaskletCounters::default(); t];

    let BcooSplit { ranges, shares_rows, n_shared, shared_bounds, .. } = split;

    for (tid, range) in ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared_bounds[tid];
        // Stream 8B of indices + dense values per block.
        acct::stream_matrix(c, range.len() * (8 + br * bc * dt.size_bytes()));
        let mut rows_touched = 0usize;
        let mut current_brow = u32::MAX;
        for bidx in range.clone() {
            let bri_u32 = slice.block_rows[bidx];
            let bri = bri_u32 as usize;
            if bri_u32 != current_brow {
                current_brow = bri_u32;
                rows_touched += 1;
            }
            let bcol = slice.block_cols[bidx] as usize;
            let blk = slice.block(bidx);
            c.instrs += calib::BLOCK_LOOP_INSTRS;
            c.instrs += (br * bc) as u64 * (calib::mac_instrs(dt) + 2);
            c.dma(bc * dt.size_bytes());
            let row0 = bri * br;
            let col0 = bcol * bc;
            let is_shared = bri_u32 == shared_head || bri_u32 == shared_tail;
            for rr in 0..br {
                let r = row0 + rr;
                if r >= slice.nrows() {
                    break;
                }
                let mut acc = T::zero();
                for cc in 0..bc {
                    let ccol = col0 + cc;
                    if ccol >= slice.ncols() {
                        break;
                    }
                    acc = T::mac(acc, blk[rr * bc + cc], x[ccol]);
                }
                if is_shared {
                    acct::locked_update(c, dt, sync);
                }
                y[r] = y[r].add(acc);
            }
        }
        acct::writeback(c, rows_touched * br, dt);
    }

    if *shares_rows && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, *n_shared * br, dt);
    }

    DpuKernelOutput::finish(cfg, y, counters)
}

/// Run the BCOO kernel on one DPU for a whole block of input vectors.
///
/// Fused SpMM-style variant of [`run_bcoo_dpu`]: the block stream is
/// walked once and every vector's accumulator advances per block
/// element, so the host-side simulation streams the slice (and runs the
/// cycle accounting) once per *vector block* instead of once per
/// vector — the same fusion as
/// [`crate::kernels::coo::run_coo_dpu_batch`]. Results are
/// bit-identical to calling [`run_bcoo_dpu`] once per vector: per
/// vector, the MAC chain over each dense block row is evaluated in the
/// same order, and the accounting is structure-only (see `finish_batch`
/// in the module root).
///
/// The tasklet walk below deliberately mirrors [`run_bcoo_dpu`]'s (a
/// shared walk would put a per-element vector loop on the single-vector
/// hot path): any change to the accounting sequence there must be
/// mirrored here, and `tests/batch_equivalence.rs` fails on any drift.
pub fn run_bcoo_dpu_batch<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcooMatrix<T>,
    xs: &[&[T]],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    run_bcoo_dpu_batch_cached(cfg, slice, xs, &bcoo_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_bcoo_dpu_batch`] with a precomputed [`BcooSplit`] (see
/// [`run_bcoo_dpu_cached`]).
pub fn run_bcoo_dpu_batch_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcooMatrix<T>,
    xs: &[&[T]],
    split: &BcooSplit,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    if xs.is_empty() {
        return Vec::new();
    }
    if xs.len() == 1 {
        return vec![run_bcoo_dpu_cached(cfg, slice, xs[0], split, sync)];
    }
    for x in xs {
        assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    }
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let (br, bc) = (slice.br, slice.bc);
    let nb = xs.len();
    let mut ys: Vec<Vec<T>> = (0..nb).map(|_| vec![T::zero(); slice.nrows()]).collect();
    let mut counters = vec![TaskletCounters::default(); t];
    let mut accs: Vec<T> = vec![T::zero(); nb];

    let BcooSplit { ranges, shares_rows, n_shared, shared_bounds, .. } = split;

    for (tid, range) in ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared_bounds[tid];
        acct::stream_matrix(c, range.len() * (8 + br * bc * dt.size_bytes()));
        let mut rows_touched = 0usize;
        let mut current_brow = u32::MAX;
        for bidx in range.clone() {
            let bri_u32 = slice.block_rows[bidx];
            let bri = bri_u32 as usize;
            if bri_u32 != current_brow {
                current_brow = bri_u32;
                rows_touched += 1;
            }
            let bcol = slice.block_cols[bidx] as usize;
            let blk = slice.block(bidx);
            c.instrs += calib::BLOCK_LOOP_INSTRS;
            c.instrs += (br * bc) as u64 * (calib::mac_instrs(dt) + 2);
            c.dma(bc * dt.size_bytes());
            let row0 = bri * br;
            let col0 = bcol * bc;
            let is_shared = bri_u32 == shared_head || bri_u32 == shared_tail;
            for rr in 0..br {
                let r = row0 + rr;
                if r >= slice.nrows() {
                    break;
                }
                accs.fill(T::zero());
                for cc in 0..bc {
                    let ccol = col0 + cc;
                    if ccol >= slice.ncols() {
                        break;
                    }
                    let v = blk[rr * bc + cc];
                    for (b, acc) in accs.iter_mut().enumerate() {
                        *acc = T::mac(*acc, v, xs[b][ccol]);
                    }
                }
                if is_shared {
                    acct::locked_update(c, dt, sync);
                }
                for (b, acc) in accs.iter().enumerate() {
                    ys[b][r] = ys[b][r].add(*acc);
                }
            }
        }
        acct::writeback(c, rows_touched * br, dt);
    }

    if *shares_rows && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, *n_shared * br, dt);
    }

    super::finish_batch(cfg, ys, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, CooMatrix};

    fn cfg(t: usize) -> PimConfig {
        PimConfig { tasklets: t, ..Default::default() }
    }

    fn check(m: &CooMatrix<f64>, brc: (usize, usize), t: usize, bal: TaskletBalance, sync: SyncScheme) {
        let b = BcooMatrix::from_coo(m, brc.0, brc.1);
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 9) as f64) - 4.0).collect();
        let out = run_bcoo_dpu(&cfg(t), &b, &x, bal, sync);
        assert_eq!(out.y, m.spmv(&x), "t={t} bal={bal:?} sync={sync:?} blk={brc:?}");
    }

    #[test]
    fn correct_across_schemes() {
        let m = generate::blocked::<f64>(24, 24, 4, 4, 13);
        for t in [1, 4, 16, 24] {
            for bal in [TaskletBalance::Rows, TaskletBalance::Blocks, TaskletBalance::Nnz] {
                for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                    check(&m, (4, 4), t, bal, sync);
                }
            }
        }
    }

    #[test]
    fn correct_on_irregular_input() {
        let m = generate::scale_free::<f64>(97, 89, 6, 0.7, 21);
        check(&m, (2, 2), 16, TaskletBalance::Blocks, SyncScheme::FineLock);
        check(&m, (8, 8), 12, TaskletBalance::Rows, SyncScheme::LockFree);
    }

    #[test]
    fn row_balance_is_lock_free() {
        let m = generate::blocked::<f64>(16, 16, 4, 4, 5);
        let b = BcooMatrix::from_coo(&m, 4, 4);
        let x = vec![1.0; m.ncols()];
        let out = run_bcoo_dpu(&cfg(8), &b, &x, TaskletBalance::Rows, SyncScheme::CoarseLock);
        let locks: u64 = out.counters.iter().map(|c| c.lock_acqs).sum();
        assert_eq!(locks, 0, "row-granularity BCOO must not lock");
    }

    #[test]
    fn block_balance_on_one_block_row_shares() {
        // All blocks in one block row: block-granularity split must sync.
        let triples: Vec<(u32, u32, f64)> = (0..256u32).map(|c| (0, c, 1.0)).collect();
        let m = CooMatrix::from_triples(2, 256, triples);
        let b = BcooMatrix::from_coo(&m, 2, 2);
        let x = vec![1.0; 256];
        let out = run_bcoo_dpu(&cfg(8), &b, &x, TaskletBalance::Blocks, SyncScheme::CoarseLock);
        let locks: u64 = out.counters.iter().map(|c| c.lock_acqs).sum();
        assert!(locks > 0, "shared block row must lock");
        assert_eq!(out.y, m.spmv(&x));
    }

    #[test]
    fn empty_ok() {
        check(&CooMatrix::<f64>::zeros(8, 8), (2, 2), 4, TaskletBalance::Blocks, SyncScheme::LockFree);
    }

    #[test]
    fn fused_batch_matches_looped_across_schemes() {
        // Irregular shape + every (balance, sync) pair: the fused walk
        // must be bit-identical to looped single-vector runs, counters
        // and timing included.
        let m = generate::scale_free::<f64>(61, 47, 5, 0.7, 29);
        let b = BcooMatrix::from_coo(&m, 4, 4);
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|s| (0..47).map(|i| ((i + 5 * s) % 11) as f64 - 5.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        for bal in [TaskletBalance::Rows, TaskletBalance::Blocks, TaskletBalance::Nnz] {
            for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                let batch = run_bcoo_dpu_batch(&cfg(16), &b, &refs, bal, sync);
                assert_eq!(batch.len(), xs.len());
                for (x, out) in xs.iter().zip(&batch) {
                    let single = run_bcoo_dpu(&cfg(16), &b, x, bal, sync);
                    assert_eq!(out.y, single.y, "{bal:?} {sync:?}: y differs");
                    assert_eq!(out.counters, single.counters, "{bal:?} {sync:?}: counters differ");
                    assert_eq!(out.timing, single.timing, "{bal:?} {sync:?}: timing differs");
                }
            }
        }
        assert!(
            run_bcoo_dpu_batch(&cfg(4), &b, &[], TaskletBalance::Blocks, SyncScheme::LockFree)
                .is_empty()
        );
    }

    #[test]
    fn batch_matches_looped_single_vector() {
        let m = generate::blocked::<f64>(24, 24, 4, 5, 17);
        let b = BcooMatrix::from_coo(&m, 4, 4);
        let xs: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..24).map(|i| ((i + 2 * s) % 7) as f64 - 3.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let batch = run_bcoo_dpu_batch(&cfg(8), &b, &refs, TaskletBalance::Blocks, SyncScheme::LockFree);
        assert_eq!(batch.len(), 3);
        for (x, out) in xs.iter().zip(&batch) {
            let single = run_bcoo_dpu(&cfg(8), &b, x, TaskletBalance::Blocks, SyncScheme::LockFree);
            assert_eq!(out.y, single.y);
            assert_eq!(out.counters, single.counters);
            assert_eq!(out.timing, single.timing);
        }
    }
}
