//! # SparseP (reproduction)
//!
//! A reproduction of *"Towards Efficient Sparse Matrix Vector Multiplication
//! on Real Processing-In-Memory Systems"* (Giannoula et al., 2022) — the
//! SparseP library of 25 SpMV kernels for near-bank PIM systems, together
//! with the substrate the paper runs on: a calibrated simulator of the
//! UPMEM PIM architecture (the first publicly-available real-world PIM
//! system), host CPU baselines, and an XLA/PJRT accelerator path fed by
//! AOT-compiled JAX/Pallas kernels.
//!
//! ## Layout
//!
//! * [`matrix`] — sparse matrix formats (COO/CSR/BCSR/BCOO), generators,
//!   MatrixMarket I/O and sparsity statistics.
//! * [`pim`] — the UPMEM-class PIM system simulator: DPU pipeline timing,
//!   WRAM/MRAM DMA model, tasklet synchronization costs, host<->PIM
//!   transfer collectives (with the real system's same-size padding rule)
//!   and the energy model.
//! * [`kernels`] — per-DPU SpMV kernels (format x tasklet-balancing x
//!   synchronization scheme), executed functionally with cycle accounting.
//! * [`partition`] — 1D and 2D matrix partitioning across DPUs, and
//!   tasklet-level load balancers.
//! * [`coordinator`] — the host-side library: plan, transfer, launch,
//!   retrieve, merge; produces the paper's load/kernel/retrieve/merge
//!   breakdowns.
//! * [`baselines`] — processor-centric comparators (multithreaded host CPU
//!   SpMV; analytic CPU/GPU roofline models).
//! * [`runtime`] — PJRT runtime that loads AOT artifacts (HLO text) built
//!   by `python/compile/aot.py` and executes them from Rust.
//! * [`bench_harness`] — a small measurement harness (criterion is not
//!   available offline) + per-figure drivers for the paper's evaluation.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sparsep::matrix::generate;
//! use sparsep::pim::PimSystem;
//! use sparsep::coordinator::{SpmvExecutor, KernelSpec};
//!
//! let m = generate::scale_free::<f32>(10_000, 10_000, 8, 0.6, 7);
//! let exec = SpmvExecutor::new(PimSystem::with_dpus(256));
//! let x = vec![1.0f32; m.ncols()];
//! let run = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
//! println!("y[0]={} breakdown={:?}", run.y[0], run.breakdown);
//! ```

pub mod util;
pub mod matrix;
pub mod pim;
pub mod kernels;
pub mod partition;
pub mod coordinator;
pub mod apps;
pub mod baselines;
pub mod runtime;
pub mod bench_harness;
pub mod cli;

pub use matrix::dtype::{DType, SpElem};
