//! 1D (horizontal) partitioning across DPUs.
//!
//! Each DPU receives a contiguous band of whole rows plus a copy of the
//! whole input vector (broadcast). The paper's 1D kernels differ in how
//! the band boundaries are chosen:
//!
//! * `Rows` — equal row counts (`CSR.row`, `COO.row`);
//! * `Nnz` — equal non-zeros at row granularity (`CSR.nnz`,
//!   `COO.nnz-rgrn`);
//! * `Blocks`/`Nnz` over block rows for BCSR/BCOO (`BCSR.block`, ...).
//!
//! The partitioner works on row *weights*, so one implementation serves
//! all four formats; block formats pass block-row weights.

use super::balance::{imbalance, split_even, split_weighted};
use crate::matrix::{CooMatrix, SpElem};
use std::ops::Range;

/// Across-DPU balancing scheme (paper §load balancing across PIM cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DpuBalance {
    /// Equal rows (block rows for blocked formats).
    Rows,
    /// Equal non-zeros at row granularity.
    Nnz,
    /// Equal non-zeros at *element* granularity (COO only): a row may
    /// span two DPUs; the host adds the boundary partials during merge.
    /// This is what lets `COO.nnz` stay balanced on scale-free matrices
    /// whose hottest row exceeds an entire DPU's fair share.
    NnzElement,
    /// Equal stored blocks at block-row granularity (blocked formats).
    Blocks,
}

impl DpuBalance {
    pub fn name(self) -> &'static str {
        match self {
            DpuBalance::Rows => "row",
            DpuBalance::Nnz => "nnz",
            DpuBalance::NnzElement => "nnz-elem",
            DpuBalance::Blocks => "block",
        }
    }
}

/// A 1D partition: per-DPU row ranges over the original matrix.
#[derive(Clone, Debug)]
pub struct OneDPartition {
    /// Row range (in original row ids) per DPU.
    pub row_ranges: Vec<Range<usize>>,
    /// Max-DPU-weight / ideal-weight (1.0 = perfect balance).
    pub imbalance: f64,
}

/// Plans 1D partitions from row weights.
pub struct OneDPartitioner;

impl OneDPartitioner {
    /// Partition `weights.len()` rows across `n_dpus` using `bal`.
    /// `weights[r]` is the balancing weight of row r (nnz for `Nnz`,
    /// ignored for `Rows`).
    pub fn plan(weights: &[usize], n_dpus: usize, bal: DpuBalance) -> OneDPartition {
        let ranges = match bal {
            DpuBalance::Rows => split_even(weights.len(), n_dpus),
            DpuBalance::Nnz | DpuBalance::Blocks => split_weighted(weights, n_dpus),
            DpuBalance::NnzElement => {
                panic!("element-granularity plans are element ranges, not row ranges; handled by the coordinator")
            }
        };
        let imb = imbalance(weights, &ranges);
        OneDPartition { row_ranges: ranges, imbalance: imb }
    }

    /// Convenience: plan directly from a COO matrix using its row nnz
    /// counts as weights.
    pub fn plan_coo<T: SpElem>(m: &CooMatrix<T>, n_dpus: usize, bal: DpuBalance) -> OneDPartition {
        let counts = m.row_counts();
        match bal {
            DpuBalance::Rows => {
                // Even row split; imbalance still reported in *nnz* terms
                // (the quantity that determines DPU kernel time).
                let ranges = split_even(m.nrows(), n_dpus);
                let imb = imbalance(&counts, &ranges);
                OneDPartition { row_ranges: ranges, imbalance: imb }
            }
            _ => Self::plan(&counts, n_dpus, bal),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    #[test]
    fn plan_covers_all_rows() {
        let w = vec![3usize; 100];
        for bal in [DpuBalance::Rows, DpuBalance::Nnz] {
            let p = OneDPartitioner::plan(&w, 8, bal);
            assert_eq!(p.row_ranges.len(), 8);
            assert_eq!(p.row_ranges[0].start, 0);
            assert_eq!(p.row_ranges.last().unwrap().end, 100);
            for w2 in p.row_ranges.windows(2) {
                assert_eq!(w2[0].end, w2[1].start);
            }
        }
    }

    #[test]
    fn nnz_balance_beats_rows_on_scale_free() {
        let m = generate::scale_free::<f64>(4096, 4096, 10, 0.7, 3);
        let rows = OneDPartitioner::plan_coo(&m, 64, DpuBalance::Rows);
        let nnz = OneDPartitioner::plan_coo(&m, 64, DpuBalance::Nnz);
        assert!(
            nnz.imbalance < rows.imbalance,
            "nnz {} !< rows {}",
            nnz.imbalance,
            rows.imbalance
        );
    }

    #[test]
    fn rows_balance_is_perfect_on_regular() {
        let m = generate::banded::<f64>(4096, 8, 1);
        let p = OneDPartitioner::plan_coo(&m, 64, DpuBalance::Rows);
        assert!((p.imbalance - 1.0).abs() < 0.05);
    }

    #[test]
    fn more_dpus_than_rows() {
        let p = OneDPartitioner::plan(&vec![1; 5], 16, DpuBalance::Nnz);
        assert_eq!(p.row_ranges.len(), 16);
        assert_eq!(p.row_ranges.last().unwrap().end, 5);
        let covered: usize = p.row_ranges.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 5);
    }
}
