//! The TCP serving front end: one event-loop thread drives every
//! connection over non-blocking `std::net` sockets, and one
//! completion-dispatch thread drains the sharded facade.
//!
//! ```text
//!                 spmv-net-event (one thread, all connections)
//!   TCP clients ──► accept / read / decode ──► ShardedService::submit_for
//!        ▲              │                            │ ticket
//!        │              └── ticket → connection map ◄┘
//!        │ frames                                    │
//!   write└───────────── encode ◄── mpsc ◄── spmv-net-dispatch
//!                                           (ShardedService::wait_next)
//! ```
//!
//! There is deliberately no thread-per-connection and no poll loop per
//! ticket: the dispatch thread parks inside the facade's completion
//! condvar ([`ShardedService::wait_next`]) and claims whichever ticket
//! finishes next, so a completion wakes exactly one thread exactly
//! once, no matter how many connections or tickets are in flight.
//!
//! Backpressure is typed, never silent, at two layers:
//!
//! * **per-connection in-flight cap** ([`ServerOpts::max_in_flight_per_conn`]):
//!   a `Submit*` arriving with the cap already reached is answered
//!   immediately with `Overloaded { ticket: 0 }` — acks are written in
//!   request order, so ticket 0 unambiguously answers that submit —
//!   and never reaches the scheduler.
//! * **per-tenant admission cap** (the facade's `max_queue`): the
//!   scheduler's own typed [`Response::Overloaded`] comes back through
//!   the dispatch thread as `Overloaded { ticket }` for the submitted
//!   ticket.
//!
//! Failures keep their types across the wire: a facade
//! `ShardTimeout { shard }` becomes an `Error` frame with
//! [`WireErrorCode::ShardTimeout`] and the shard number, which
//! [`crate::net::Client`] turns back into
//! [`crate::util::Error::shard_timeout`] — locked end to end by
//! `tests/net_equivalence.rs`.

use crate::coordinator::queue::BufferPool;
use crate::coordinator::{KernelSpec, Request, Response, ShardedHandle, ShardedService};
use crate::matrix::CooMatrix;
use crate::net::protocol::{decode_stream, Completion, Frame, WireErrorCode};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::mpsc::{channel, Receiver};
use crate::util::sync::{thread, Arc};
use crate::util::{Context, Error, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// Read staging size; also the pooled-buffer length, so every read
/// recycles through one [`BufferPool`] slot.
const READ_CHUNK: usize = 64 * 1024;
/// How long the dispatch thread parks in [`ShardedService::wait_next`]
/// per shutdown-flag check.
const DISPATCH_TICK: Duration = Duration::from_millis(25);
/// Event-loop sleep when a tick saw no I/O and no completions.
const IDLE_SLEEP: Duration = Duration::from_micros(500);

/// Tuning knobs for [`Server::spawn`].
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Submitted-but-unanswered requests allowed per connection before
    /// the server sheds with `Overloaded { ticket: 0 }` instead of
    /// submitting. A cap of 0 sheds every submit (useful in tests).
    pub max_in_flight_per_conn: usize,
}

impl Default for ServerOpts {
    fn default() -> ServerOpts {
        ServerOpts { max_in_flight_per_conn: 64 }
    }
}

/// A running `sparsep serve --listen` instance: the listener plus the
/// two threads described in the module docs. Dropping the server shuts
/// both down and joins them; open connections see EOF.
pub struct Server {
    addr: SocketAddr,
    svc: Arc<ShardedService<f64>>,
    shutdown: Arc<AtomicBool>,
    event: Option<thread::JoinHandle<()>>,
    dispatch: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `svc` on background threads. The server becomes
    /// the facade's only completion consumer — callers must not also
    /// `wait` on tickets they submit in-process.
    pub fn spawn(svc: ShardedService<f64>, addr: &str, opts: ServerOpts) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind listener on {addr}"))?;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        let local = listener.local_addr().context("query bound listener address")?;
        let svc = Arc::new(svc);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel::<(u64, Result<Response<f64>>)>();

        let dsvc = Arc::clone(&svc);
        let dstop = Arc::clone(&shutdown);
        let dispatch = thread::spawn_named("spmv-net-dispatch", move || {
            while !dstop.load(Ordering::SeqCst) {
                if let Some((ticket, resp)) = dsvc.wait_next(DISPATCH_TICK) {
                    if tx.send((ticket.id(), resp)).is_err() {
                        break; // event loop is gone; nothing to serve
                    }
                }
            }
        });

        let estop = Arc::clone(&shutdown);
        let esvc = Arc::clone(&svc);
        let event = thread::spawn_named("spmv-net-event", move || {
            EventLoop {
                listener,
                rx,
                svc: esvc,
                opts,
                shutdown: estop,
                pool: BufferPool::new(0u8),
                conns: HashMap::new(),
                tickets: HashMap::new(),
                next_conn: 1,
            }
            .run();
        });

        Ok(Server { addr: local, svc, shutdown, event: Some(event), dispatch: Some(dispatch) })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The facade being served (tests use this to `pause`/`resume` and
    /// to read stats; do not `wait` on it — see [`Server::spawn`]).
    pub fn service(&self) -> &ShardedService<f64> {
        &self.svc
    }

    /// Stop both threads and join them. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.event.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection state owned by the event loop.
struct Conn {
    id: usize,
    stream: TcpStream,
    /// Bytes read but not yet framed.
    rbuf: Vec<u8>,
    /// Encoded frames not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Wire handle -> facade handle, private to this connection.
    handles: HashMap<u64, ShardedHandle>,
    next_handle: u64,
    /// Submitted-but-unanswered requests (the shed cap's counter).
    in_flight: usize,
    /// Close once `wbuf` drains (set on protocol violations, after the
    /// error frame is queued).
    closing: bool,
}

struct EventLoop {
    listener: TcpListener,
    rx: Receiver<(u64, Result<Response<f64>>)>,
    svc: Arc<ShardedService<f64>>,
    opts: ServerOpts,
    shutdown: Arc<AtomicBool>,
    pool: BufferPool<u8>,
    conns: HashMap<usize, Conn>,
    /// Facade ticket id -> connection id. Inserted by the same loop
    /// iteration that submits (before the completion channel is next
    /// drained), so a completion can never arrive unmapped.
    tickets: HashMap<u64, usize>,
    next_conn: usize,
}

impl EventLoop {
    fn run(mut self) {
        while !self.shutdown.load(Ordering::SeqCst) {
            let mut activity = false;

            // Accept everything pending.
            loop {
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue; // the socket is unusable; drop it
                        }
                        let _ = stream.set_nodelay(true);
                        let id = self.next_conn;
                        self.next_conn += 1;
                        self.conns.insert(
                            id,
                            Conn {
                                id,
                                stream,
                                rbuf: Vec::new(),
                                wbuf: Vec::new(),
                                handles: HashMap::new(),
                                next_handle: 1,
                                in_flight: 0,
                                closing: false,
                            },
                        );
                        activity = true;
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }

            // Read and process each connection's pending bytes.
            let ids: Vec<usize> = self.conns.keys().copied().collect();
            for id in ids {
                let mut conn = self.conns.remove(&id).expect("connection ids are stable");
                let alive = self.service_conn(&mut conn, &mut activity);
                if alive {
                    self.conns.insert(id, conn);
                }
            }

            // Route completions claimed by the dispatch thread.
            while let Ok((ticket, resp)) = self.rx.try_recv() {
                self.route_completion(ticket, resp);
                activity = true;
            }

            // Flush pending writes; drop connections that are done.
            let mut wrote = false;
            self.conns.retain(|_, conn| {
                if !conn.wbuf.is_empty() {
                    wrote = true;
                    if !flush_conn(conn) {
                        return false;
                    }
                }
                !(conn.closing && conn.wbuf.is_empty())
            });
            activity |= wrote;

            if !activity {
                std::thread::sleep(IDLE_SLEEP);
            }
        }
    }

    /// Read whatever the socket has, decode complete frames, handle
    /// them. Returns false when the connection is gone.
    fn service_conn(&mut self, conn: &mut Conn, activity: &mut bool) -> bool {
        let mut chunk = self.pool.take_zeroed(READ_CHUNK);
        let mut alive = true;
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    alive = false; // orderly EOF
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    *activity = true;
                    if n < chunk.len() {
                        break;
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    alive = false;
                    break;
                }
            }
        }
        self.pool.put(chunk);

        let mut consumed = 0;
        while !conn.closing {
            match decode_stream(&conn.rbuf[consumed..]) {
                Ok(Some((frame, n))) => {
                    consumed += n;
                    self.handle_frame(conn, frame);
                }
                Ok(None) => break,
                Err(e) => {
                    // Corrupt stream: answer with a typed conn-level
                    // error, then close once it flushes.
                    error_frame(0, &e).encode_into(&mut conn.wbuf);
                    conn.closing = true;
                }
            }
        }
        conn.rbuf.drain(..consumed);
        // A dead connection with queued writes can't be saved; a dead
        // one with none is dropped here. Closing conns stay until the
        // write phase drains them.
        alive || !conn.wbuf.is_empty()
    }

    fn handle_frame(&mut self, conn: &mut Conn, frame: Frame) {
        match frame {
            Frame::LoadMatrix { tenant, kernel, stripes, nrows, ncols, triples } => {
                self.load_matrix(conn, &tenant, &kernel, stripes, nrows, ncols, triples);
            }
            Frame::SubmitSpmv { tenant, handle, deadline_ms, x } => {
                self.submit(conn, &tenant, handle, deadline_ms, Request::spmv(x));
            }
            Frame::SubmitBatch { tenant, handle, deadline_ms, xs } => {
                self.submit(conn, &tenant, handle, deadline_ms, Request::batch(xs));
            }
            Frame::SubmitIterate { tenant, handle, deadline_ms, iters, x } => {
                self.submit(conn, &tenant, handle, deadline_ms, Request::iterate(x, iters as usize));
            }
            Frame::Poll { ticket } => {
                // Answered from the server's own ticket map, never from
                // the completions store — the dispatch thread is its
                // only consumer, so polling can't race a claim.
                let frame = if self.tickets.get(&ticket) == Some(&conn.id) {
                    Frame::NotReady { ticket }
                } else {
                    Frame::Error {
                        ticket,
                        code: WireErrorCode::Other,
                        shard: None,
                        message: format!("unknown ticket {ticket}"),
                    }
                };
                frame.encode_into(&mut conn.wbuf);
            }
            // Server->client frames arriving at the server: protocol
            // violation; answer typed, then close.
            other => {
                error_frame(0, &Error::msg(format!("unexpected client frame {other:?}")))
                    .encode_into(&mut conn.wbuf);
                conn.closing = true;
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn load_matrix(
        &mut self,
        conn: &mut Conn,
        tenant: &str,
        kernel: &str,
        stripes: u32,
        nrows: u64,
        ncols: u64,
        triples: Vec<(u32, u32, f64)>,
    ) {
        let r = (|| -> Result<Frame> {
            let t = self
                .svc
                .tenant(tenant)
                .ok_or_else(|| Error::msg(format!("unknown tenant {tenant:?}")))?;
            let spec = KernelSpec::by_name(kernel, (stripes.max(1)) as usize)
                .ok_or_else(|| Error::msg(format!("unknown kernel {kernel:?}")))?;
            let m = CooMatrix::<f64>::from_triples(nrows as usize, ncols as usize, triples);
            let h = self.svc.load_for(t, &m, &spec)?;
            let wire = conn.next_handle;
            conn.next_handle += 1;
            conn.handles.insert(wire, h);
            Ok(Frame::Loaded { handle: wire, nrows: h.nrows() as u64, ncols: h.ncols() as u64 })
        })();
        match r {
            Ok(frame) => frame.encode_into(&mut conn.wbuf),
            Err(e) => error_frame(0, &e).encode_into(&mut conn.wbuf),
        }
    }

    fn submit(
        &mut self,
        conn: &mut Conn,
        tenant: &str,
        wire_handle: u64,
        deadline_ms: u32,
        req: Request<f64>,
    ) {
        if conn.in_flight >= self.opts.max_in_flight_per_conn {
            // Connection-level shed: answered before submission, in
            // request order, so ticket 0 is unambiguous.
            Frame::Overloaded { ticket: 0 }.encode_into(&mut conn.wbuf);
            return;
        }
        let r = (|| -> Result<u64> {
            let t = self
                .svc
                .tenant(tenant)
                .ok_or_else(|| Error::msg(format!("unknown tenant {tenant:?}")))?;
            let h = *conn
                .handles
                .get(&wire_handle)
                .ok_or_else(|| Error::msg(format!("unknown matrix handle {wire_handle}")))?;
            let ticket = if deadline_ms > 0 {
                self.svc.submit_with_deadline(t, h, req, Duration::from_millis(deadline_ms as u64))?
            } else {
                self.svc.submit_for(t, h, req)?
            };
            Ok(ticket.id())
        })();
        match r {
            Ok(ticket) => {
                self.tickets.insert(ticket, conn.id);
                conn.in_flight += 1;
                Frame::Submitted { ticket }.encode_into(&mut conn.wbuf);
            }
            Err(e) => error_frame(0, &e).encode_into(&mut conn.wbuf),
        }
    }

    fn route_completion(&mut self, ticket: u64, resp: Result<Response<f64>>) {
        let Some(conn_id) = self.tickets.remove(&ticket) else {
            return; // server bug shield; tickets are always mapped
        };
        let Some(conn) = self.conns.get_mut(&conn_id) else {
            return; // connection closed while the request ran
        };
        conn.in_flight = conn.in_flight.saturating_sub(1);
        let frame = match resp {
            Ok(Response::Overloaded) => Frame::Overloaded { ticket },
            Ok(Response::Spmv(r)) => {
                Frame::Completion { ticket, body: Box::new(Completion::Spmv(r)) }
            }
            Ok(Response::Batch(b)) => {
                Frame::Completion { ticket, body: Box::new(Completion::Batch(b)) }
            }
            Ok(Response::Iterate(it)) => {
                Frame::Completion { ticket, body: Box::new(Completion::Iterate(it)) }
            }
            Err(e) => error_frame(ticket, &e),
        };
        frame.encode_into(&mut conn.wbuf);
    }
}

/// Translate a facade error into its typed wire twin.
fn error_frame(ticket: u64, e: &Error) -> Frame {
    if e.is_shard_timeout() {
        Frame::Error {
            ticket,
            code: WireErrorCode::ShardTimeout,
            shard: e.timed_out_shard().map(|s| s as u32),
            message: e.to_string(),
        }
    } else {
        Frame::Error { ticket, code: WireErrorCode::Other, shard: None, message: e.to_string() }
    }
}

/// Push queued bytes into the socket. Returns false when the
/// connection died under the write.
fn flush_conn(conn: &mut Conn) -> bool {
    while !conn.wbuf.is_empty() {
        match conn.stream.write(&conn.wbuf) {
            Ok(0) => return false,
            Ok(n) => {
                conn.wbuf.drain(..n);
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Engine, ShardedServiceBuilder, TenantSpec};
    use crate::matrix::generate;
    use crate::net::client::Client;
    use crate::pim::PimSystem;

    fn matrix() -> CooMatrix<f64> {
        generate::scale_free::<f64>(48, 48, 4, 0.7, 9)
    }

    fn server(opts: ServerOpts) -> (Server, CooMatrix<f64>) {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .engine(Engine::Serial)
            .tenants(vec![TenantSpec::new("alice", 2), TenantSpec::new("bob", 1)])
            .build(PimSystem::with_dpus(4))
            .expect("sharded service builds");
        let srv = Server::spawn(svc, "127.0.0.1:0", opts).expect("server binds");
        (srv, matrix())
    }

    fn x_for(m: &CooMatrix<f64>) -> Vec<f64> {
        (0..m.ncols()).map(|i| ((i % 5) as f64) - 2.0).collect()
    }

    #[test]
    fn end_to_end_spmv_over_tcp() {
        let (srv, m) = server(ServerOpts::default());
        let mut cl = Client::connect(srv.local_addr()).expect("client connects");
        let h = cl.load("alice", &m, "COO.nnz", 8).expect("load over the wire");
        let x = x_for(&m);
        let t = cl.submit_spmv("alice", h, x.clone(), None).expect("submit");
        let run = cl.wait(t).expect("wait").into_spmv().expect("spmv response");
        assert_eq!(run.y, m.spmv(&x), "served result must match the host oracle");
    }

    /// With the per-connection cap at 0 every submit sheds as a typed
    /// `Overloaded` before reaching the scheduler — and the connection
    /// stays fully usable afterwards.
    #[test]
    fn conn_cap_sheds_and_client_survives() {
        let (srv, m) = server(ServerOpts { max_in_flight_per_conn: 0 });
        let mut cl = Client::connect(srv.local_addr()).expect("client connects");
        let h = cl.load("bob", &m, "COO.nnz", 8).expect("load is not capped");
        let x = x_for(&m);
        for _ in 0..3 {
            let t = cl.submit_spmv("bob", h, x.clone(), None).expect("shed is not an error");
            let resp = cl.wait(t).expect("shed ticket is claimable");
            assert!(resp.is_overloaded(), "cap 0 must shed every request");
        }
    }

    #[test]
    fn poll_reports_not_ready_then_completion() {
        let (srv, m) = server(ServerOpts::default());
        srv.service().pause();
        let mut cl = Client::connect(srv.local_addr()).expect("client connects");
        let h = cl.load("alice", &m, "COO.nnz", 8).expect("load");
        let x = x_for(&m);
        let t = cl.submit_spmv("alice", h, x.clone(), None).expect("submit while paused");
        assert!(
            cl.poll(t).expect("poll answers").is_none(),
            "a paused service must report the ticket in flight"
        );
        srv.service().resume();
        let run = cl.wait(t).expect("wait after resume").into_spmv().expect("spmv");
        assert_eq!(run.y, m.spmv(&x));
    }

    #[test]
    fn unknown_tenant_and_kernel_are_typed_errors() {
        let (srv, m) = server(ServerOpts::default());
        let mut cl = Client::connect(srv.local_addr()).expect("client connects");
        let e = cl.load("zed", &m, "COO.nnz", 8).expect_err("unknown tenant must fail");
        assert!(e.to_string().contains("zed"), "error names the tenant: {e}");
        let e = cl.load("alice", &m, "NOPE.kernel", 8).expect_err("unknown kernel must fail");
        assert!(e.to_string().contains("NOPE"), "error names the kernel: {e}");
        // The connection survives both rejections.
        let h = cl.load("alice", &m, "COO.nnz", 8).expect("load still works");
        let x = x_for(&m);
        let t = cl.submit_spmv("alice", h, x.clone(), None).expect("submit still works");
        assert_eq!(cl.wait(t).unwrap().into_spmv().unwrap().y, m.spmv(&x));
    }

    /// A server going away mid-stream surfaces as a typed error on the
    /// client, not a panic or a hang.
    #[test]
    fn client_survives_mid_stream_disconnect() {
        let (mut srv, m) = server(ServerOpts::default());
        srv.service().pause(); // park the request so the shutdown races nothing
        let mut cl = Client::connect(srv.local_addr()).expect("client connects");
        let h = cl.load("alice", &m, "COO.nnz", 8).expect("load");
        let t = cl.submit_spmv("alice", h, x_for(&m), None).expect("submit");
        srv.service().resume();
        srv.shutdown();
        // The parked ticket either completed before the shutdown (fine)
        // or the socket died under the wait (typed error, not a panic).
        match cl.wait(t) {
            Ok(resp) => assert_eq!(resp.kind(), "spmv"),
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("closed") || msg.contains("read from server"),
                    "disconnect must be a typed transport error: {msg}"
                );
            }
        }
        // Every call after the disconnect keeps failing cleanly.
        let e = cl.submit_spmv("alice", h, x_for(&m), None);
        if let Ok(t2) = e {
            assert!(cl.wait(t2).is_err(), "a dead connection cannot complete tickets");
        }
    }

    /// Garbage bytes on the socket get a typed conn-level error frame
    /// back before the server closes the connection.
    #[test]
    fn garbage_stream_is_rejected_with_typed_error() {
        let (srv, _m) = server(ServerOpts::default());
        let mut raw = TcpStream::connect(srv.local_addr()).expect("connect");
        raw.write_all(b"definitely not a SPRP frame").expect("write garbage");
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).expect("server answers then closes");
        let (frame, _) = decode_stream(&buf)
            .expect("the reply is a well-formed frame")
            .expect("the reply is complete");
        match frame {
            Frame::Error { ticket: 0, code: WireErrorCode::Other, .. } => {}
            other => panic!("expected a conn-level error frame, got {other:?}"),
        }
    }
}
