//! Sharded-serving wall-clock benchmark (`sparsep bench-shard`).
//!
//! Measures what spreading one logical matrix across `S` simulated rank
//! groups buys: the same batched request stream served by a
//! [`ShardedService`] at shard counts {1, 2, 4, 8} (each shard its own
//! backend pipeline over `dpus_per_shard` DPUs), on the serial and
//! threaded engines. Gathered outputs are verified against the host
//! oracle once per configuration; shard count never changes answers
//! (locked by `tests/shard_equivalence.rs`), only wall clock.
//!
//! The matrix is loaded (shard planning + per-slice plans) once per
//! facade before any timing. The JSON summary lands in
//! `BENCH_shard.json` next to the other `BENCH_*.json` trajectories.

use crate::coordinator::{Engine, KernelSpec, Request, ShardedService, ShardedServiceBuilder};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{arr, num, obj, s};
use crate::util::{Context, Result};
use std::time::Instant;

/// Shard counts every run sweeps.
pub const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Knobs for [`run`] (CLI flags of `sparsep bench-shard`).
#[derive(Clone, Debug)]
pub struct ShardBenchOpts {
    /// Matrix dimension (square, scale-free class).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// Batched requests per measurement.
    pub requests: usize,
    /// Right-hand-side vectors per request.
    pub batch: usize,
    /// Simulated DPUs per shard (each shard is one rank group).
    pub dpus_per_shard: usize,
    /// Threaded-engine worker count (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per configuration (min is reported).
    pub samples: usize,
    /// Output JSON path.
    pub out: String,
}

impl Default for ShardBenchOpts {
    fn default() -> ShardBenchOpts {
        ShardBenchOpts {
            rows: 50_000,
            deg: 8,
            requests: 8,
            batch: 8,
            dpus_per_shard: 64,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            out: "BENCH_shard.json".to_string(),
        }
    }
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &ShardBenchOpts) -> Result<()> {
    crate::ensure!(opts.requests >= 1, "bench-shard needs --requests >= 1");
    crate::ensure!(opts.batch >= 1, "bench-shard needs --batch >= 1");
    crate::ensure!(opts.samples >= 1, "bench-shard needs --samples >= 1");
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    let payloads: Vec<Vec<Vec<f64>>> = (0..opts.requests)
        .map(|r| {
            (0..opts.batch)
                .map(|b| {
                    (0..m.ncols()).map(|i| ((i + 3 * b + 7 * r) % 9) as f64 - 4.0).collect()
                })
                .collect()
        })
        .collect();
    let sys = PimSystem::new(PimConfig { n_dpus: opts.dpus_per_shard, ..Default::default() })?;
    println!(
        "bench-shard: {} x{} requests x{} vectors on {}x{} ({} nnz), {} DPUs/shard, shards {:?}",
        spec.name,
        opts.requests,
        opts.batch,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.dpus_per_shard,
        SHARD_COUNTS
    );

    let one = |engine: Engine, shards: usize, verify: bool| -> Result<f64> {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .engine(engine)
            .build(sys.clone())?;
        let handle = svc.load(&m, &spec)?; // shard planning + plans, out of timing
        if verify {
            let b = svc.spmv_batch(&handle, &payloads[0])?;
            for (x, run) in payloads[0].iter().zip(&b.runs) {
                crate::ensure!(run.y == m.spmv(x), "sharded output diverged from host oracle");
            }
        }
        let mut best = f64::INFINITY;
        for _ in 0..opts.samples {
            // Payload Arcs built outside the clock; the facade's scatter
            // shares them across shards instead of copying per shard.
            let owned: Vec<Vec<crate::util::sync::Arc<[f64]>>> = payloads
                .iter()
                .map(|xs| xs.iter().map(|v| crate::util::sync::Arc::from(&v[..])).collect())
                .collect();
            let t0 = Instant::now();
            let tickets: Vec<_> = owned
                .into_iter()
                .map(|xs| svc.submit(handle, Request::Batch { xs }))
                .collect::<Result<_>>()?;
            for t in tickets {
                let resp = svc.wait(t)?.into_batch()?;
                std::hint::black_box(&resp.runs.last().unwrap().y);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok(best)
    };

    let mut serial_walls = Vec::with_capacity(SHARD_COUNTS.len());
    let mut threaded_walls = Vec::with_capacity(SHARD_COUNTS.len());
    for (i, &shards) in SHARD_COUNTS.iter().enumerate() {
        let serial = one(Engine::Serial, shards, i == 0)?;
        let threaded = one(Engine::threaded(opts.threads), shards, false)?;
        println!(
            "  shards {:>2}: serial {:>8.3}s | threaded {:>8.3}s | serial 1-shard/{}-shard {:>5.2}x",
            shards,
            serial,
            threaded,
            shards,
            serial_walls.first().copied().unwrap_or(serial) / serial.max(1e-12)
        );
        serial_walls.push(serial);
        threaded_walls.push(threaded);
    }

    let j = obj(vec![
        ("bench", s("sharded_service_scaling")),
        ("kernel", s(&spec.name)),
        ("rows", num(m.nrows() as f64)),
        ("nnz", num(m.nnz() as f64)),
        ("requests", num(opts.requests as f64)),
        ("batch", num(opts.batch as f64)),
        ("dpus_per_shard", num(opts.dpus_per_shard as f64)),
        ("host_threads", num(opts.threads as f64)),
        ("samples", num(opts.samples as f64)),
        ("shard_counts", arr(SHARD_COUNTS.iter().map(|&c| num(c as f64)).collect())),
        ("serial_wall_s", arr(serial_walls.iter().map(|&w| num(w)).collect())),
        ("threaded_wall_s", arr(threaded_walls.iter().map(|&w| num(w)).collect())),
        (
            "serial_speedup_max_shards",
            num(serial_walls[0] / serial_walls.last().copied().unwrap_or(1.0).max(1e-12)),
        ),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_shard_smoke_writes_json() {
        let dir = std::env::temp_dir().join("sparsep_bench_shard_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_shard_test.json");
        let opts = ShardBenchOpts {
            rows: 300,
            deg: 4,
            requests: 2,
            batch: 3,
            dpus_per_shard: 4,
            threads: 2,
            samples: 1,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("sharded_service_scaling"));
        assert_eq!(j.get("shard_counts").as_arr().unwrap().len(), SHARD_COUNTS.len());
        assert_eq!(j.get("serial_wall_s").as_arr().unwrap().len(), SHARD_COUNTS.len());
        assert!(j.get("threaded_wall_s").as_arr().unwrap()[0].as_f64().unwrap() > 0.0);
        std::fs::remove_file(&out).ok();
    }
}
