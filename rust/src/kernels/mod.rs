//! Per-DPU SpMV kernels.
//!
//! Each kernel is the simulator-side equivalent of one SparseP DPU
//! program: it computes the exact partial SpMV result for the matrix
//! slice resident in one DPU's MRAM, while counting per-tasklet
//! instructions, DMA traffic and synchronization events for the timing
//! model in [`crate::pim::dpu`].
//!
//! The kernel axes follow the paper:
//! * format — CSR / COO / BCSR / BCOO ([`csr`], [`coo`], [`bcsr`],
//!   [`bcoo`]);
//! * load balancing across tasklets — rows / nnz (/ blocks for the
//!   blocked formats), [`TaskletBalance`];
//! * synchronization among tasklets — lock-free, coarse-grained mutex,
//!   fine-grained mutex, [`SyncScheme`].
//!
//! Every kernel also has a batched (multi-vector) entry point
//! (`run_*_dpu_batch`) used by the SpMM-style serving path in
//! [`crate::coordinator`]: all four formats fuse the batch into one
//! pass over the matrix slice (accounting once, every vector's
//! accumulator advanced per element/block), so a vector block streams
//! the slice once instead of once per vector. Per-vector results are
//! bit-identical to single-vector runs (locked by
//! `tests/batch_equivalence.rs`).

pub mod bcoo;
pub mod bcsr;
pub mod coo;
pub mod csr;

use crate::matrix::SpElem;
use crate::pim::{dpu_time, DpuTiming, PimConfig, TaskletCounters};

/// Work division across the tasklets of one DPU (paper §load balancing
/// across threads of a multithreaded PIM core).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskletBalance {
    /// Equal row (or block-row) counts per tasklet.
    Rows,
    /// Equal non-zeros per tasklet at row granularity (rows stay whole).
    Nnz,
    /// Equal non-zeros per tasklet at element granularity (rows may be
    /// split across tasklets -> output synchronization required).
    /// COO/BCOO only: CSR's implicit row boundaries cannot express it.
    NnzElement,
    /// Equal block counts per tasklet (BCSR/BCOO only). Blocks in the
    /// same block row may land on different tasklets -> synchronization.
    Blocks,
}

impl TaskletBalance {
    pub fn name(self) -> &'static str {
        match self {
            TaskletBalance::Rows => "row",
            TaskletBalance::Nnz => "nnz",
            TaskletBalance::NnzElement => "nnz-elem",
            TaskletBalance::Blocks => "block",
        }
    }
}

/// Synchronization scheme for tasklets that share output rows (paper
/// §synchronization approaches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SyncScheme {
    /// Private per-tasklet accumulators for shared rows, merged by
    /// tasklet 0 after a barrier.
    LockFree,
    /// One global mutex around every shared-row update.
    CoarseLock,
    /// An array of 32 mutexes hashed by row index. On real UPMEM this
    /// does *not* beat coarse locking: critical sections serialize on
    /// the shared DMA engine anyway (hardware recommendation #1) — the
    /// timing model reproduces that.
    FineLock,
}

impl SyncScheme {
    pub fn name(self) -> &'static str {
        match self {
            SyncScheme::LockFree => "lock-free",
            SyncScheme::CoarseLock => "coarse-lock",
            SyncScheme::FineLock => "fine-lock",
        }
    }

    /// Extra instructions per acquisition beyond the mutex itself
    /// (fine-grained pays a hash + index computation).
    pub(crate) fn acquire_overhead_instrs(self) -> u64 {
        match self {
            SyncScheme::FineLock => 3,
            _ => 0,
        }
    }
}

/// Plan-time per-tasklet split for one DPU slice, format-matched to the
/// slice's compressed representation. The execution plan computes one
/// per work item at plan time (for the planning system's tasklet
/// count), so kernels stop re-running their O(nrows)/O(nnz)/O(nblocks)
/// split passes on every invocation — iterative apps and batched
/// serving pay the split exactly once per (matrix, spec) pair. Kernels
/// executed under a *different* tasklet count (plans may legitimately
/// be swept across tasklet configurations) fall back to computing the
/// split on the fly.
#[derive(Clone, Debug)]
pub enum TaskletSplit {
    Csr(csr::CsrSplit),
    Coo(coo::CooSplit),
    Bcsr(bcsr::BcsrSplit),
    Bcoo(bcoo::BcooSplit),
}

impl TaskletSplit {
    /// Tasklet count this split was computed for.
    pub fn tasklets(&self) -> usize {
        match self {
            TaskletSplit::Csr(s) => s.tasklets,
            TaskletSplit::Coo(s) => s.tasklets,
            TaskletSplit::Bcsr(s) => s.tasklets,
            TaskletSplit::Bcoo(s) => s.tasklets,
        }
    }
}

/// Result of running one DPU kernel.
#[derive(Clone, Debug)]
pub struct DpuKernelOutput<T: SpElem> {
    /// Exact partial result for the DPU's local rows.
    pub y: Vec<T>,
    /// Per-tasklet counters (length = cfg.tasklets).
    pub counters: Vec<TaskletCounters>,
    /// Timing under the DPU model.
    pub timing: DpuTiming,
}

impl<T: SpElem> DpuKernelOutput<T> {
    pub(crate) fn finish(
        cfg: &PimConfig,
        y: Vec<T>,
        counters: Vec<TaskletCounters>,
    ) -> DpuKernelOutput<T> {
        let timing = dpu_time(cfg, &counters);
        DpuKernelOutput { y, counters, timing }
    }
}

/// Package the per-vector outputs of a batched (multi-vector) kernel
/// that share one set of tasklet counters.
///
/// Kernel accounting is *structure-only*: instruction, DMA and
/// synchronization counts depend on the matrix slice, the balancing
/// scheme and the sync scheme — never on the input vector's values. A
/// batched kernel therefore runs the accounting exactly once and every
/// vector in the batch gets counters (and timing) bit-identical to a
/// single-vector run — the equivalence the batch execution path
/// guarantees and `tests/batch_equivalence.rs` locks in.
pub(crate) fn finish_batch<T: SpElem>(
    cfg: &PimConfig,
    ys: Vec<Vec<T>>,
    counters: Vec<TaskletCounters>,
) -> Vec<DpuKernelOutput<T>> {
    let timing = dpu_time(cfg, &counters);
    ys.into_iter()
        .map(|y| DpuKernelOutput { y, counters: counters.clone(), timing })
        .collect()
}

/// Common per-kernel accounting helpers.
pub(crate) mod acct {
    use super::*;
    use crate::matrix::DType;
    use crate::pim::calib;

    /// Account one inner-loop element: loop overhead + MAC + x gather.
    ///
    /// `x_bytes` is the element size of the input vector; SparseP
    /// gathers x[col] from MRAM per non-zero (x does not fit in WRAM).
    #[inline]
    pub fn element(c: &mut TaskletCounters, dt: DType) {
        c.instrs += calib::ELEM_LOOP_INSTRS + calib::mac_instrs(dt);
        c.dma(dt.size_bytes());
    }

    /// Account one row: setup + y accumulation bookkeeping. The y value
    /// itself lives in WRAM and is written back by a trailing stream.
    #[inline]
    pub fn row(c: &mut TaskletCounters) {
        c.instrs += calib::ROW_LOOP_INSTRS;
    }

    /// Account streaming the matrix-slice bytes a tasklet consumes
    /// (row pointers / indices / values move MRAM->WRAM in 2 KB tiles).
    #[inline]
    pub fn stream_matrix(c: &mut TaskletCounters, bytes: usize) {
        c.stream(bytes);
    }

    /// Account writing back `rows` output values of type `dt`.
    #[inline]
    pub fn writeback(c: &mut TaskletCounters, rows: usize, dt: DType) {
        c.stream(rows * dt.size_bytes());
        c.instrs += 2 * rows as u64; // store + pointer bump per value
    }

    /// Account a synchronized update of one shared output value.
    pub fn locked_update(c: &mut TaskletCounters, dt: DType, sync: SyncScheme) {
        match sync {
            SyncScheme::LockFree => {
                // Private accumulator in WRAM: just an add.
                c.instrs += calib::add_instrs(dt);
            }
            SyncScheme::CoarseLock | SyncScheme::FineLock => {
                c.lock_acqs += 1;
                c.instrs += sync.acquire_overhead_instrs();
                // Critical section: read-modify-write of the shared WRAM
                // accumulator (adds), counted as CS work so the model
                // serializes it across tasklets.
                let cs = calib::add_instrs(dt) + 4;
                c.cs_instrs += cs;
                c.instrs += cs;
            }
        }
    }

    /// Account the lock-free merge epilogue: after a barrier, tasklet 0
    /// folds every tasklet's private boundary accumulators.
    pub fn lockfree_merge(
        counters: &mut [TaskletCounters],
        shared_rows: usize,
        dt: DType,
    ) {
        if shared_rows == 0 {
            return;
        }
        for c in counters.iter_mut() {
            c.barriers += 1;
        }
        let n = counters.len();
        counters[0].instrs += (shared_rows * n) as u64 * (calib::add_instrs(dt) + 2);
    }
}

/// Convenience: total kernel cycles across DPUs = max (DPUs run in
/// parallel and the host waits for the slowest — the paper's inter-DPU
/// balance metric).
pub fn slowest_dpu_cycles(outputs: &[DpuTiming]) -> u64 {
    outputs.iter().map(|t| t.cycles).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(TaskletBalance::Rows.name(), "row");
        assert_eq!(SyncScheme::FineLock.name(), "fine-lock");
    }

    #[test]
    fn fine_lock_costs_more_instrs() {
        assert!(
            SyncScheme::FineLock.acquire_overhead_instrs()
                > SyncScheme::CoarseLock.acquire_overhead_instrs()
        );
    }

    #[test]
    fn locked_update_produces_cs_work() {
        let mut c = TaskletCounters::default();
        acct::locked_update(&mut c, crate::matrix::DType::F32, SyncScheme::CoarseLock);
        assert_eq!(c.lock_acqs, 1);
        assert!(c.cs_instrs > 0);
        let mut lf = TaskletCounters::default();
        acct::locked_update(&mut lf, crate::matrix::DType::F32, SyncScheme::LockFree);
        assert_eq!(lf.lock_acqs, 0);
        assert_eq!(lf.cs_instrs, 0);
    }

    #[test]
    fn lockfree_merge_bills_tasklet0() {
        let mut cs = vec![TaskletCounters::default(); 4];
        acct::lockfree_merge(&mut cs, 10, crate::matrix::DType::I32);
        assert!(cs[0].instrs > 0);
        assert_eq!(cs[1].instrs, 0);
        assert!(cs.iter().all(|c| c.barriers == 1));
    }
}
