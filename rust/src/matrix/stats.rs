//! Sparsity statistics — the columns of the paper's matrix-suite table
//! (Table 2): rows, columns, non-zeros, nnz-per-row mean / stddev / CV,
//! density. The CV of nnz/row is the statistic the paper uses to split
//! the suite into regular vs scale-free matrices and to explain when
//! nnz-balanced schemes beat row-balanced ones.

use super::coo::CooMatrix;
use super::dtype::SpElem;
use crate::util::{cv, mean, stddev};

/// Summary statistics of a sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixStats {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    pub nnz_per_row_mean: f64,
    pub nnz_per_row_stddev: f64,
    /// Coefficient of variation of nnz/row; > ~0.5 = "scale-free" class.
    pub nnz_per_row_cv: f64,
    pub max_row_nnz: usize,
    pub min_row_nnz: usize,
    pub empty_rows: usize,
    /// nnz / (nrows * ncols).
    pub density: f64,
}

impl MatrixStats {
    pub fn of<T: SpElem>(m: &CooMatrix<T>) -> MatrixStats {
        let counts = m.row_counts();
        let cf: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        MatrixStats {
            nrows: m.nrows(),
            ncols: m.ncols(),
            nnz: m.nnz(),
            nnz_per_row_mean: mean(&cf),
            nnz_per_row_stddev: stddev(&cf),
            nnz_per_row_cv: cv(&cf),
            max_row_nnz: counts.iter().copied().max().unwrap_or(0),
            min_row_nnz: counts.iter().copied().min().unwrap_or(0),
            empty_rows: counts.iter().filter(|&&c| c == 0).count(),
            density: if m.nrows() * m.ncols() == 0 {
                0.0
            } else {
                m.nnz() as f64 / (m.nrows() as f64 * m.ncols() as f64)
            },
        }
    }

    /// Feature vector for calibration-table lookups
    /// ([`crate::coordinator::calibration`]): the statistics the paper's
    /// analysis keys on, log-scaled where the raw value spans orders of
    /// magnitude so nearest-neighbor distances behave. Components:
    /// log2 rows, log2 cols, log2 mean nnz/row, CV of nnz/row, the
    /// class indicator (1 = scale-free), log10 density.
    pub fn feature_vector(&self) -> [f64; 6] {
        [
            (self.nrows.max(1) as f64).log2(),
            (self.ncols.max(1) as f64).log2(),
            self.nnz_per_row_mean.max(1.0).log2(),
            self.nnz_per_row_cv,
            if self.nnz_per_row_cv > 0.5 { 1.0 } else { 0.0 },
            self.density.max(1e-12).log10(),
        ]
    }

    /// The paper's two-way classification.
    pub fn class(&self) -> &'static str {
        if self.nnz_per_row_cv > 0.5 {
            "scale-free"
        } else {
            "regular"
        }
    }

    /// One table row, formatted like the paper's Table 2.
    pub fn table_row(&self, name: &str) -> String {
        format!(
            "{:<10} {:>9} {:>9} {:>10} {:>8.1} {:>8.2} {:>6.2} {:>11}",
            name,
            self.nrows,
            self.ncols,
            self.nnz,
            self.nnz_per_row_mean,
            self.nnz_per_row_stddev,
            self.nnz_per_row_cv,
            self.class()
        )
    }

    pub fn table_header() -> String {
        format!(
            "{:<10} {:>9} {:>9} {:>10} {:>8} {:>8} {:>6} {:>11}",
            "matrix", "rows", "cols", "nnz", "nnz/row", "stddev", "cv", "class"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    #[test]
    fn stats_of_banded() {
        let m = generate::banded::<f64>(100, 4, 1);
        let s = MatrixStats::of(&m);
        assert_eq!(s.nnz, 400);
        assert_eq!(s.nnz_per_row_mean, 4.0);
        assert_eq!(s.nnz_per_row_cv, 0.0);
        assert_eq!(s.class(), "regular");
        assert_eq!(s.empty_rows, 0);
    }

    #[test]
    fn stats_of_scale_free() {
        let m = generate::scale_free::<f64>(2048, 2048, 8, 0.6, 2);
        let s = MatrixStats::of(&m);
        assert_eq!(s.class(), "scale-free");
        assert!(s.max_row_nnz > 4 * s.min_row_nnz.max(1));
    }

    #[test]
    fn feature_vector_is_finite_and_class_sensitive() {
        let reg = MatrixStats::of(&generate::banded::<f64>(512, 8, 1));
        let sf = MatrixStats::of(&generate::scale_free::<f64>(2048, 2048, 8, 0.6, 2));
        for f in reg.feature_vector().iter().chain(sf.feature_vector().iter()) {
            assert!(f.is_finite());
        }
        assert_eq!(reg.feature_vector()[4], 0.0);
        assert_eq!(sf.feature_vector()[4], 1.0);
        // Empty-ish matrices don't produce -inf features.
        let tiny = MatrixStats::of(&generate::diagonal::<f64>(1, 1));
        assert!(tiny.feature_vector().iter().all(|f| f.is_finite()));
    }

    #[test]
    fn density() {
        let m = generate::diagonal::<f32>(64, 1);
        let s = MatrixStats::of(&m);
        assert!((s.density - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn table_row_formats() {
        let m = generate::banded::<f64>(10, 2, 1);
        let s = MatrixStats::of(&m);
        let row = s.table_row("band");
        assert!(row.contains("band"));
        assert!(row.contains("regular"));
    }
}
