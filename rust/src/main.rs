//! `sparsep` — the SparseP reproduction CLI.
//!
//! The leader process of the three-layer stack: it owns the simulated
//! PIM system, the SpMV kernel library, the baselines and the PJRT
//! runtime for AOT artifacts. Run `sparsep help` for commands.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match sparsep::cli::Args::parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            sparsep::cli::print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = sparsep::cli::run(parsed) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
