"""Pallas block-ELL (BCSR-like) SpMV kernel (layer 1).

The blocked counterpart of `ell_spmv`: the paper's BCSR format exists to
amortize index overhead over a dense micro-tile, which on a DPU means
one x-strip DMA per block, and on a TPU means the dense `BR x BC` blocks
can hit the MXU as small matmuls. Each grid step processes one *block
row*: `BMAX` dense blocks, a gathered `(BMAX, BC)` bundle of x strips,
and a `jnp.einsum` contraction that XLA maps onto the matrix unit.

MXU-utilization estimate (DESIGN.md §Perf): with BR=BC=8 and BMAX=16 a
grid step issues a (8x128)x(128x8)-equivalent contraction; at fp32 on an
MXU-128 that is ~6% utilization per block row — small, as expected for
SpMV (memory-bound); the win over scalar ELL is the 1/BC reduction in
gather count, the same ratio the DPU kernel enjoys.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bell_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """One grid step: SpMV for one block row (BMAX blocks of BR x BC)."""
    vals = vals_ref[0]  # (BMAX, BR, BC)
    cols = cols_ref[0]  # (BMAX,) int32 block-column ids
    x = x_ref[...]  # (N,)
    bmax, br, bc = vals.shape
    # Gather x strips for every block slot: (BMAX, BC).
    idx = cols[:, None] * bc + jnp.arange(bc)[None, :]
    xg = x[idx]
    # Dense contraction: sum_b vals[b] @ xg[b] -> (BR,). Padding slots
    # have zero blocks, so they are harmless.
    y_ref[...] = jnp.einsum("brc,bc->r", vals, xg)


@jax.jit
def bell_spmv(vals, cols, x):
    """Block-ELL SpMV via Pallas: y = A @ x.

    Args:
      vals: (NBR, BMAX, BR, BC) dense blocks, zero-filled padding slots.
      cols: (NBR, BMAX) int32 block-column indices (padding -> 0).
      x:    (N,) input vector, N == n_block_cols * BC.

    Returns:
      (NBR * BR,) output vector.
    """
    nbr, bmax, br, bc = vals.shape
    n = x.shape[0]
    return pl.pallas_call(
        _bell_kernel,
        grid=(nbr,),
        in_specs=[
            pl.BlockSpec((1, bmax, br, bc), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, bmax), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nbr * br,), vals.dtype),
        interpret=True,
    )(vals, cols, x)
