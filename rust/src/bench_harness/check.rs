//! Bench regression gate (`sparsep bench-check`).
//!
//! The `BENCH_*.json` trajectories carry relative quality statistics
//! that hold *by construction* — tuned-vs-heuristic speedups, the
//! grid sweep's row-only floor — so they make honest regression
//! guards: if one dips, the harness or the serving stack broke, not
//! the machine. This command compares the current bench outputs
//! against a committed baseline manifest and hard-fails on any
//! shortfall beyond a configurable tolerance, giving `scripts/ci.sh`
//! and `scripts/bench_smoke.sh` a single exit-status gate.
//!
//! The baseline manifest (`scripts/bench_baseline.json`) is a list of
//! checks:
//!
//! ```json
//! {"checks": [
//!   {"file": "BENCH_tune.json", "field": "min_speedup", "min": 1.0}
//! ]}
//! ```
//!
//! Each check asserts `report[field] >= min * (1 - tolerance)`. Only
//! machine-independent ratio statistics belong here — absolute
//! wall-clocks vary across hosts and would make the gate flaky.
//!
//! A bench file may legitimately be absent (CI runs a subset of the
//! benches); `--missing skip` reports and skips those checks, while
//! `--missing fail` (the full `bench_smoke.sh` pass, which runs every
//! bench) treats absence itself as a regression.

use crate::util::json::Json;
use crate::util::{Context, Result};
use std::path::Path;

/// Knobs for [`run`] (CLI flags of `sparsep bench-check`).
#[derive(Clone, Debug)]
pub struct CheckOpts {
    /// Path to the baseline manifest.
    pub baseline: String,
    /// Directory the manifest's `file` entries resolve against.
    pub dir: String,
    /// Tolerated relative shortfall below each `min` (0.25 = pass at
    /// 75% of the baseline value). Absorbs measurement noise without
    /// letting a by-construction invariant collapse silently.
    pub tolerance: f64,
    /// What a missing bench file means: `skip` (report, don't fail) or
    /// `fail` (the file was expected — hard error).
    pub missing: String,
}

impl Default for CheckOpts {
    fn default() -> CheckOpts {
        CheckOpts {
            baseline: "scripts/bench_baseline.json".to_string(),
            dir: ".".to_string(),
            tolerance: 0.25,
            missing: "skip".to_string(),
        }
    }
}

/// One parsed baseline check.
#[derive(Clone, Debug, PartialEq)]
struct Check {
    file: String,
    field: String,
    min: f64,
}

fn parse_checks(doc: &Json) -> Result<Vec<Check>> {
    let arr = doc
        .get("checks")
        .as_arr()
        .context("bench baseline: missing top-level \"checks\" array")?;
    let mut checks = Vec::with_capacity(arr.len());
    for (i, c) in arr.iter().enumerate() {
        checks.push(Check {
            file: c
                .get("file")
                .as_str()
                .with_context(|| format!("bench baseline: checks[{i}] missing \"file\""))?
                .to_string(),
            field: c
                .get("field")
                .as_str()
                .with_context(|| format!("bench baseline: checks[{i}] missing \"field\""))?
                .to_string(),
            min: c
                .get("min")
                .as_f64()
                .with_context(|| format!("bench baseline: checks[{i}] missing \"min\""))?,
        });
    }
    Ok(checks)
}

/// Run every baseline check; `Err` if any fails (or is missing under
/// `--missing fail`).
pub fn run(opts: &CheckOpts) -> Result<()> {
    crate::ensure!(
        (0.0..1.0).contains(&opts.tolerance),
        "bench-check needs --tolerance in [0, 1), got {}",
        opts.tolerance
    );
    crate::ensure!(
        opts.missing == "skip" || opts.missing == "fail",
        "bench-check needs --missing skip|fail, got {}",
        opts.missing
    );
    let text = std::fs::read_to_string(&opts.baseline)
        .with_context(|| format!("read bench baseline {}", opts.baseline))?;
    let doc = Json::parse(&text)
        .map_err(|e| crate::format_err!("parse bench baseline {}: {e}", opts.baseline))?;
    let checks = parse_checks(&doc)?;
    crate::ensure!(!checks.is_empty(), "bench baseline {} has no checks", opts.baseline);

    let mut failures = Vec::new();
    let mut skipped = 0usize;
    for c in &checks {
        let path = Path::new(&opts.dir).join(&c.file);
        let floor = c.min * (1.0 - opts.tolerance);
        let Ok(body) = std::fs::read_to_string(&path) else {
            if opts.missing == "fail" {
                failures.push(format!("{}: bench file missing ({})", c.file, path.display()));
            } else {
                println!("bench-check: SKIP {} ({} not present)", c.field, c.file);
                skipped += 1;
            }
            continue;
        };
        // A present-but-unreadable report is always a failure: the bench
        // ran and produced rot.
        let report = match Json::parse(&body) {
            Ok(j) => j,
            Err(e) => {
                failures.push(format!("{}: unparseable report: {e}", c.file));
                continue;
            }
        };
        match report.get(&c.field).as_f64() {
            Some(v) if v >= floor => {
                println!(
                    "bench-check: OK   {}::{} = {v:.4} >= {floor:.4} (baseline {:.4})",
                    c.file, c.field, c.min
                );
            }
            Some(v) => {
                failures.push(format!(
                    "{}::{} = {v:.4} < {floor:.4} (baseline {:.4}, tolerance {})",
                    c.file, c.field, c.min, opts.tolerance
                ));
            }
            None => {
                failures.push(format!("{}: field {} missing or non-numeric", c.file, c.field));
            }
        }
    }
    let ran = checks.len() - skipped;
    println!(
        "bench-check: {} checks, {ran} ran, {skipped} skipped, {} failed",
        checks.len(),
        failures.len()
    );
    crate::ensure!(
        failures.is_empty(),
        "bench regression gate failed:\n  {}",
        failures.join("\n  ")
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write(dir: &Path, name: &str, body: &str) -> String {
        let p = dir.join(name);
        std::fs::write(&p, body).unwrap();
        p.to_str().unwrap().to_string()
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sparsep_bench_check_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn opts_for(dir: &Path, baseline: String, missing: &str) -> CheckOpts {
        CheckOpts {
            baseline,
            dir: dir.to_str().unwrap().to_string(),
            tolerance: 0.25,
            missing: missing.to_string(),
        }
    }

    #[test]
    fn passes_within_tolerance_and_fails_below() {
        let dir = temp_dir("pass_fail");
        write(&dir, "BENCH_x.json", r#"{"min_speedup": 0.80}"#);
        let baseline = write(
            &dir,
            "baseline.json",
            r#"{"checks": [{"file": "BENCH_x.json", "field": "min_speedup", "min": 1.0}]}"#,
        );
        // 0.80 >= 1.0 * (1 - 0.25): inside tolerance.
        run(&opts_for(&dir, baseline.clone(), "skip")).unwrap();
        // Below the floor: gate trips and names the statistic.
        write(&dir, "BENCH_x.json", r#"{"min_speedup": 0.50}"#);
        let err = run(&opts_for(&dir, baseline, "skip")).unwrap_err();
        assert!(err.to_string().contains("min_speedup"), "{err}");
    }

    #[test]
    fn missing_file_policy_is_respected() {
        let dir = temp_dir("missing");
        let baseline = write(
            &dir,
            "baseline.json",
            r#"{"checks": [{"file": "BENCH_absent.json", "field": "f", "min": 1.0}]}"#,
        );
        run(&opts_for(&dir, baseline.clone(), "skip")).unwrap();
        let err = run(&opts_for(&dir, baseline, "fail")).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
    }

    #[test]
    fn missing_field_and_bad_manifest_always_fail() {
        let dir = temp_dir("field");
        write(&dir, "BENCH_y.json", r#"{"other": 2.0}"#);
        let baseline = write(
            &dir,
            "baseline.json",
            r#"{"checks": [{"file": "BENCH_y.json", "field": "gone", "min": 1.0}]}"#,
        );
        let err = run(&opts_for(&dir, baseline, "skip")).unwrap_err();
        assert!(err.to_string().contains("gone"), "{err}");

        let empty = write(&dir, "empty.json", r#"{"checks": []}"#);
        assert!(run(&opts_for(&dir, empty, "skip")).is_err());
        let bad = write(&dir, "bad.json", r#"{"nope": 1}"#);
        assert!(run(&opts_for(&dir, bad, "skip")).is_err());
    }
}
