//! Kernel specification — the naming scheme of SparseP's 25 kernels.
//!
//! A [`KernelSpec`] pins down every axis the library exposes: compressed
//! format, data partitioning (1D with an across-DPU balancing scheme, or
//! 2D with a tile-shaping scheme and stripe count), block shape for the
//! blocked formats, tasklet-level balancing, and the synchronization
//! scheme. [`KernelSpec::all25`] enumerates the paper's 25 named kernels.

use crate::kernels::{SyncScheme, TaskletBalance};
use crate::matrix::Format;
use crate::partition::{DpuBalance, TwoDScheme};

/// Data partitioning axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partitioning {
    /// Horizontal: whole rows per DPU + broadcast of the full vector.
    OneD(DpuBalance),
    /// Tiled: `n_col_stripes` vertical stripes, x-slices scattered,
    /// partial outputs gathered and merged on the host.
    TwoD(TwoDScheme, usize),
}

/// Full specification of one SpMV kernel configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelSpec {
    /// Paper-style kernel name (e.g. "CSR.nnz", "RBDCOO").
    pub name: String,
    pub format: Format,
    pub partitioning: Partitioning,
    /// Block shape for BCSR/BCOO (ignored otherwise).
    pub block: (usize, usize),
    /// Work division across tasklets within a DPU.
    pub tasklet_balance: TaskletBalance,
    /// Synchronization among tasklets sharing output rows.
    pub sync: SyncScheme,
}

impl KernelSpec {
    fn new(
        name: &str,
        format: Format,
        partitioning: Partitioning,
        tasklet_balance: TaskletBalance,
        sync: SyncScheme,
    ) -> KernelSpec {
        KernelSpec {
            name: name.to_string(),
            format,
            partitioning,
            block: (4, 4),
            tasklet_balance,
            sync,
        }
    }

    /// Override the block shape (BCSR/BCOO).
    pub fn with_block(mut self, br: usize, bc: usize) -> Self {
        self.block = (br, bc);
        self
    }

    /// Override the synchronization scheme.
    pub fn with_sync(mut self, sync: SyncScheme) -> Self {
        self.sync = sync;
        self
    }

    /// Override the tasklet balancing.
    pub fn with_tasklet_balance(mut self, tb: TaskletBalance) -> Self {
        self.tasklet_balance = tb;
        self
    }

    /// Override the 2D stripe count (no-op for 1D specs).
    pub fn with_stripes(mut self, stripes: usize) -> Self {
        if let Partitioning::TwoD(s, _) = self.partitioning {
            self.partitioning = Partitioning::TwoD(s, stripes);
        }
        self
    }

    // --- the paper's 1D kernels -------------------------------------

    /// `CSR.row`: CSR, rows balanced across DPUs and tasklets.
    pub fn csr_row() -> KernelSpec {
        Self::new(
            "CSR.row",
            Format::Csr,
            Partitioning::OneD(DpuBalance::Rows),
            TaskletBalance::Rows,
            SyncScheme::LockFree,
        )
    }

    /// `CSR.nnz`: CSR, nnz balanced (row granularity) everywhere.
    pub fn csr_nnz() -> KernelSpec {
        Self::new(
            "CSR.nnz",
            Format::Csr,
            Partitioning::OneD(DpuBalance::Nnz),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// `COO.row`: COO, row-balanced.
    pub fn coo_row() -> KernelSpec {
        Self::new(
            "COO.row",
            Format::Coo,
            Partitioning::OneD(DpuBalance::Rows),
            TaskletBalance::Rows,
            SyncScheme::LockFree,
        )
    }

    /// `COO.nnz-rgrn`: COO, nnz balanced at row granularity.
    pub fn coo_nnz_rgrn() -> KernelSpec {
        Self::new(
            "COO.nnz-rgrn",
            Format::Coo,
            Partitioning::OneD(DpuBalance::Nnz),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// `COO.nnz`: COO, nnz balanced at element granularity both across
    /// DPUs (rows may span two DPUs; host merges boundary partials) and
    /// across tasklets (shared rows; sync scheme applies — default
    /// lock-free).
    pub fn coo_nnz() -> KernelSpec {
        Self::new(
            "COO.nnz",
            Format::Coo,
            Partitioning::OneD(DpuBalance::NnzElement),
            TaskletBalance::NnzElement,
            SyncScheme::LockFree,
        )
    }

    /// `BCSR.block`: BCSR, blocks balanced (block granularity + sync).
    pub fn bcsr_block() -> KernelSpec {
        Self::new(
            "BCSR.block",
            Format::Bcsr,
            Partitioning::OneD(DpuBalance::Blocks),
            TaskletBalance::Blocks,
            SyncScheme::CoarseLock,
        )
    }

    /// `BCSR.nnz`: BCSR, nnz balanced at block-row granularity.
    pub fn bcsr_nnz() -> KernelSpec {
        Self::new(
            "BCSR.nnz",
            Format::Bcsr,
            Partitioning::OneD(DpuBalance::Nnz),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// `BCOO.block`: BCOO, block-balanced.
    pub fn bcoo_block() -> KernelSpec {
        Self::new(
            "BCOO.block",
            Format::Bcoo,
            Partitioning::OneD(DpuBalance::Blocks),
            TaskletBalance::Blocks,
            SyncScheme::CoarseLock,
        )
    }

    /// `BCOO.nnz`: BCOO, nnz-balanced.
    pub fn bcoo_nnz() -> KernelSpec {
        Self::new(
            "BCOO.nnz",
            Format::Bcoo,
            Partitioning::OneD(DpuBalance::Nnz),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    // --- the paper's 2D kernels -------------------------------------

    /// Equally-sized tiles (`DCSR`, `DCOO`, `DBCSR`, `DBCOO`).
    pub fn two_d(format: Format, stripes: usize) -> KernelSpec {
        let name = match format {
            Format::Csr => "DCSR",
            Format::Coo => "DCOO",
            Format::Bcsr => "DBCSR",
            Format::Bcoo => "DBCOO",
        };
        Self::new(
            name,
            format,
            Partitioning::TwoD(TwoDScheme::EquallySized, stripes),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// Equally-wide tiles (`RBDCSR`, `RBDCOO`, `RBDBCSR`, `RBDBCOO`).
    pub fn two_d_equally_wide(format: Format, stripes: usize) -> KernelSpec {
        let name = match format {
            Format::Csr => "RBDCSR",
            Format::Coo => "RBDCOO",
            Format::Bcsr => "RBDBCSR",
            Format::Bcoo => "RBDBCOO",
        };
        Self::new(
            name,
            format,
            Partitioning::TwoD(TwoDScheme::EquallyWide, stripes),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// Balanced-nnz tiles (`BDCSR`, `BDCOO`, `BDBCSR`, `BDBCOO`).
    pub fn two_d_balanced(format: Format, stripes: usize) -> KernelSpec {
        let name = match format {
            Format::Csr => "BDCSR",
            Format::Coo => "BDCOO",
            Format::Bcsr => "BDBCSR",
            Format::Bcoo => "BDBCOO",
        };
        Self::new(
            name,
            format,
            Partitioning::TwoD(TwoDScheme::BalancedNnz, stripes),
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        )
    }

    /// The paper's 25 kernels: 9 x 1D, 12 x 2D (3 schemes x 4 formats),
    /// plus the 4 tasklet-axis variants the paper counts separately
    /// (`CSR.tsklt-row`, `CSR.tsklt-nnz`, `COO.tsklt-row`,
    /// `COO.tsklt-nnz`: DPU-level nnz balance combined with the opposite
    /// tasklet-level scheme).
    pub fn all25(stripes: usize) -> Vec<KernelSpec> {
        let mut v = vec![
            Self::csr_row(),
            Self::csr_nnz(),
            Self::coo_row(),
            Self::coo_nnz_rgrn(),
            Self::coo_nnz(),
            Self::bcsr_block(),
            Self::bcsr_nnz(),
            Self::bcoo_block(),
            Self::bcoo_nnz(),
        ];
        for f in Format::all() {
            v.push(Self::two_d(f, stripes));
        }
        for f in Format::all() {
            v.push(Self::two_d_equally_wide(f, stripes));
        }
        for f in Format::all() {
            v.push(Self::two_d_balanced(f, stripes));
        }
        // Tasklet-axis variants (22-25).
        let mut k = Self::csr_nnz();
        k.name = "CSR.tsklt-row".into();
        k.tasklet_balance = TaskletBalance::Rows;
        v.push(k);
        let mut k = Self::csr_row();
        k.name = "CSR.tsklt-nnz".into();
        k.tasklet_balance = TaskletBalance::Nnz;
        v.push(k);
        let mut k = Self::coo_nnz_rgrn();
        k.name = "COO.tsklt-row".into();
        k.tasklet_balance = TaskletBalance::Rows;
        v.push(k);
        let mut k = Self::coo_row();
        k.name = "COO.tsklt-nnz".into();
        k.tasklet_balance = TaskletBalance::Nnz;
        v.push(k);
        v
    }

    /// Look a kernel up by its paper name.
    pub fn by_name(name: &str, stripes: usize) -> Option<KernelSpec> {
        Self::all25(stripes).into_iter().find(|k| k.name == name)
    }

    /// Is this a 2D kernel?
    pub fn is_two_d(&self) -> bool {
        matches!(self.partitioning, Partitioning::TwoD(..))
    }

    /// The 2D stripe count (`None` for 1D kernels, where the axis does
    /// not exist). This is what the autotuner records in a calibration
    /// entry so the winning spec can be reconstructed on load.
    pub fn stripes(&self) -> Option<usize> {
        match self.partitioning {
            Partitioning::OneD(_) => None,
            Partitioning::TwoD(_, n) => Some(n),
        }
    }
}

impl std::fmt::Display for KernelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all25_has_25_distinct_names() {
        let v = KernelSpec::all25(4);
        assert_eq!(v.len(), 25);
        let names: std::collections::HashSet<_> = v.iter().map(|k| k.name.clone()).collect();
        assert_eq!(names.len(), 25, "kernel names must be unique");
    }

    #[test]
    fn by_name_roundtrips() {
        for k in KernelSpec::all25(8) {
            let found = KernelSpec::by_name(&k.name, 8).unwrap();
            assert_eq!(found.name, k.name);
            assert_eq!(found.format, k.format);
        }
        assert!(KernelSpec::by_name("NOPE", 4).is_none());
    }

    #[test]
    fn builders_apply() {
        let k = KernelSpec::bcsr_nnz().with_block(8, 8).with_sync(SyncScheme::FineLock);
        assert_eq!(k.block, (8, 8));
        assert_eq!(k.sync, SyncScheme::FineLock);
        let k2 = KernelSpec::two_d(Format::Coo, 4).with_stripes(16);
        assert_eq!(k2.partitioning, Partitioning::TwoD(TwoDScheme::EquallySized, 16));
    }

    #[test]
    fn two_d_flags() {
        assert!(!KernelSpec::csr_row().is_two_d());
        assert!(KernelSpec::two_d(Format::Csr, 2).is_two_d());
        assert_eq!(KernelSpec::csr_row().stripes(), None);
        assert_eq!(KernelSpec::two_d(Format::Csr, 2).stripes(), Some(2));
        assert_eq!(KernelSpec::two_d(Format::Coo, 4).with_stripes(16).stripes(), Some(16));
    }
}
