"""Pure-jnp correctness oracles for the Pallas SpMV kernels.

These are the ground truth the pytest suite checks every kernel against
(the paper's methodology: every SpMV kernel is validated against a simple
reference before being measured).
"""

import jax.numpy as jnp


def ell_spmv_ref(vals, cols, x):
    """Reference ELL SpMV.

    Args:
      vals: (R, K) padded per-row values (0 in padding slots).
      cols: (R, K) int32 column indices (padding points at column 0).
      x:    (N,) input vector.

    Returns:
      (R,) output vector.
    """
    return jnp.sum(vals * x[cols], axis=1)


def bell_spmv_ref(vals, cols, x):
    """Reference block-ELL SpMV.

    Args:
      vals: (NBR, BMAX, BR, BC) dense blocks; slot b of block row i holds
        a BRxBC tile (zero-filled for unused slots).
      cols: (NBR, BMAX) int32 block-column indices (padding -> 0).
      x:    (N,) input vector with N == n_block_cols * BC.

    Returns:
      (NBR * BR,) output vector.
    """
    nbr, bmax, br, bc = vals.shape
    # Gather x strips: (NBR, BMAX, BC).
    idx = cols[..., None] * bc + jnp.arange(bc)[None, None, :]
    xg = x[idx]
    # Block matvec + reduce over slots: (NBR, BR).
    y = jnp.einsum("ibrc,ibc->ir", vals, xg)
    return y.reshape(nbr * br)


def dense_spmv_ref(a, x):
    """Dense mat-vec, the baseline compute path."""
    return a @ x
