//! Matrix partitioning across PIM cores.
//!
//! SparseP's two families (paper contribution #2):
//!
//! * **1D** ([`one_d`]): the matrix is split horizontally; each DPU gets
//!   whole rows and the *entire* input vector is broadcast to every DPU.
//!   Computation balance is controlled by the row/nnz/block balancing
//!   schemes; the broadcast is the scaling wall.
//! * **2D** ([`two_d`]): the matrix is split into tiles; each DPU gets a
//!   tile and only the matching *slice* of the input vector, trading
//!   balance and partial-result merging for lower transfer volume.
//!
//! [`balance`] holds the weighted-range splitting shared by both and by
//! the tasklet-level balancers inside the kernels.

pub mod balance;
pub mod one_d;
pub mod two_d;

pub use one_d::{OneDPartitioner, OneDPartition, DpuBalance};
pub use two_d::{TwoDPartitioner, TwoDPartition, TwoDScheme};
