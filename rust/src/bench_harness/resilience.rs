//! Resilience-tier benchmark (`sparsep bench-resilience`).
//!
//! Two measurements over the sharded multi-tenant facade:
//!
//! 1. **Recovery overhead** — the same SpMV request stream served twice
//!    at the same shard count: once fault-free, once under a seeded
//!    [`FaultPlan`] that kills one shard backend at every request's
//!    dispatch. Every kill forces a supervised respawn from the shared
//!    plan cache plus a re-scatter of the affected sub-request, so the
//!    wall-clock ratio is the end-to-end price of recovery. Outputs are
//!    verified against the host oracle in both modes — recovery never
//!    changes answers (locked by `tests/chaos_equivalence.rs`).
//!
//! 2. **Shed behaviour** — a paused facade with a per-tenant admission
//!    cap is offered more requests than it will admit. Sheds are typed
//!    ([`Response::Overloaded`]) and deterministic
//!    (`offered - max_queue` of them), and the survivors' latency
//!    distribution comes straight from the per-tenant histograms
//!    (p50/p99/p999).
//!
//! The chaos seed is printed up front so any failure reproduces with
//! the same fault schedule. The JSON summary lands in
//! `BENCH_resilience.json` next to the other `BENCH_*.json` files.

use crate::coordinator::{
    Engine, Fault, FaultPlan, KernelSpec, Request, Response, ShardedService,
    ShardedServiceBuilder,
};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{num, obj, s};
use crate::util::{Context, Result};
use crate::util::sync::Arc;
use std::time::Instant;

/// Knobs for [`run`] (CLI flags of `sparsep bench-resilience`).
#[derive(Clone, Debug)]
pub struct ResilienceBenchOpts {
    /// Matrix dimension (square, scale-free class).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// SpMV requests per measured stream.
    pub requests: usize,
    /// Shard count for both facades.
    pub shards: usize,
    /// Simulated DPUs per shard.
    pub dpus_per_shard: usize,
    /// Threaded-engine worker count (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per mode (min is reported).
    pub samples: usize,
    /// Per-tenant admission cap for the shed measurement.
    pub max_queue: usize,
    /// Requests offered to the capped facade (> max_queue sheds).
    pub offered: usize,
    /// Fault-plan seed (printed; failures reproduce from it).
    pub seed: u64,
    /// Output JSON path.
    pub out: String,
}

impl Default for ResilienceBenchOpts {
    fn default() -> ResilienceBenchOpts {
        ResilienceBenchOpts {
            rows: 20_000,
            deg: 8,
            requests: 8,
            shards: 4,
            dpus_per_shard: 16,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            max_queue: 4,
            offered: 16,
            seed: 0xC4A0_5EED,
            out: "BENCH_resilience.json".to_string(),
        }
    }
}

/// Kill plan for the chaos stream: every queued request's dispatch
/// kills one shard, round-robin over the shard count, so each measured
/// request pays a respawn + re-scatter. `tickets` must cover every
/// sample's submissions (facade ticket ids keep counting across
/// samples) — otherwise later samples would run fault-free and the
/// min-of-samples would measure the clean path.
fn kill_every_request(seed: u64, tickets: usize, shards: usize) -> FaultPlan {
    let mut plan = FaultPlan::new(seed);
    for t in 1..=tickets as u64 {
        plan = plan.on_dispatch(t, Fault::KillShard { shard: (t as usize - 1) % shards });
    }
    plan
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &ResilienceBenchOpts) -> Result<()> {
    crate::ensure!(opts.requests >= 1, "bench-resilience needs --requests >= 1");
    crate::ensure!(opts.shards >= 1, "bench-resilience needs --shards >= 1");
    crate::ensure!(opts.samples >= 1, "bench-resilience needs --samples >= 1");
    crate::ensure!(opts.max_queue >= 1, "bench-resilience needs --max-queue >= 1");
    crate::ensure!(
        opts.offered > opts.max_queue,
        "bench-resilience needs --offered > --max-queue (otherwise nothing sheds)"
    );
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    let xs: Vec<Vec<f64>> = (0..opts.requests.max(opts.offered))
        .map(|r| (0..m.ncols()).map(|i| ((i + 5 * r) % 9) as f64 - 4.0).collect())
        .collect();
    let sys = PimSystem::new(PimConfig { n_dpus: opts.dpus_per_shard, ..Default::default() })?;
    let engine = Engine::threaded(opts.threads);
    println!(
        "bench-resilience: {} x{} requests on {}x{} ({} nnz), {} shards x {} DPUs, chaos seed {:#x}",
        spec.name,
        opts.requests,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.shards,
        opts.dpus_per_shard,
        opts.seed
    );

    // -- Measurement 1: recovery overhead ---------------------------------
    let stream = |plan: Option<FaultPlan>| -> Result<(f64, u64)> {
        let mut b = ShardedServiceBuilder::new().shards(opts.shards).engine(engine);
        if let Some(p) = plan {
            b = b.fault_injector(Arc::new(p));
        }
        let svc: ShardedService<f64> = b.build(sys.clone())?;
        let handle = svc.load(&m, &spec)?;
        // Verify once, out of timing: recovery must not change answers.
        let r = svc.spmv(&handle, &xs[0])?;
        crate::ensure!(r.y == m.spmv(&xs[0]), "sharded output diverged from host oracle");
        let mut best = f64::INFINITY;
        for _ in 0..opts.samples {
            let t0 = Instant::now();
            let tickets: Vec<_> = xs[..opts.requests]
                .iter()
                .map(|x| svc.submit(handle, Request::spmv(x.clone())))
                .collect::<Result<_>>()?;
            for t in tickets {
                let run = svc.wait(t)?.into_spmv()?;
                std::hint::black_box(&run.y);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok((best, svc.stats().respawns))
    };
    let (clean_wall, clean_respawns) = stream(None)?;
    crate::ensure!(clean_respawns == 0, "fault-free stream must not respawn");
    let plan = kill_every_request(opts.seed, opts.requests * opts.samples, opts.shards);
    let (chaos_wall, chaos_respawns) = stream(Some(plan))?;
    crate::ensure!(
        chaos_respawns >= (opts.requests * opts.samples) as u64,
        "kill plan must force a respawn per measured request"
    );
    let overhead = chaos_wall / clean_wall.max(1e-12);
    println!(
        "  recovery: fault-free {clean_wall:>8.3}s | kill-per-request {chaos_wall:>8.3}s \
         ({overhead:>5.2}x, {chaos_respawns} respawns)"
    );

    // -- Measurement 2: typed shedding under overload ---------------------
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(opts.shards)
        .engine(engine)
        .max_queue(opts.max_queue)
        .start_paused(true)
        .build(sys.clone())?;
    let handle = svc.load(&m, &spec)?;
    let tickets: Vec<_> = xs[..opts.offered]
        .iter()
        .map(|x| svc.submit(handle, Request::spmv(x.clone())))
        .collect::<Result<_>>()?;
    svc.resume();
    let mut served = 0usize;
    let mut shed = 0usize;
    for t in tickets {
        match svc.wait(t)? {
            Response::Overloaded => shed += 1,
            resp => {
                std::hint::black_box(&resp.into_spmv()?.y);
                served += 1;
            }
        }
    }
    let want_shed = opts.offered - opts.max_queue;
    crate::ensure!(
        (served, shed) == (opts.max_queue, want_shed),
        "expected {} served / {} shed, got {} / {}",
        opts.max_queue,
        want_shed,
        served,
        shed
    );
    let st = svc.stats();
    let lat = &st.tenants[0].latency;
    crate::ensure!(lat.count == served as u64, "latency histogram must count served only");
    let shed_rate = shed as f64 / opts.offered as f64;
    println!(
        "  shedding: offered {} cap {} -> {} served / {} shed ({:.0}% shed rate), \
         latency p50 {}us p99 {}us p999 {}us",
        opts.offered, opts.max_queue, served, shed, 100.0 * shed_rate,
        lat.p50_us, lat.p99_us, lat.p999_us
    );

    let j = obj(vec![
        ("bench", s("resilience_tier")),
        ("kernel", s(&spec.name)),
        ("rows", num(m.nrows() as f64)),
        ("nnz", num(m.nnz() as f64)),
        ("shards", num(opts.shards as f64)),
        ("dpus_per_shard", num(opts.dpus_per_shard as f64)),
        ("host_threads", num(opts.threads as f64)),
        ("requests", num(opts.requests as f64)),
        ("samples", num(opts.samples as f64)),
        ("chaos_seed", num(opts.seed as f64)),
        ("clean_wall_s", num(clean_wall)),
        ("chaos_wall_s", num(chaos_wall)),
        ("recovery_overhead_x", num(overhead)),
        ("respawns", num(chaos_respawns as f64)),
        ("offered", num(opts.offered as f64)),
        ("max_queue", num(opts.max_queue as f64)),
        ("served", num(served as f64)),
        ("shed", num(shed as f64)),
        ("shed_rate", num(shed_rate)),
        ("served_p50_us", num(lat.p50_us as f64)),
        ("served_p99_us", num(lat.p99_us as f64)),
        ("served_p999_us", num(lat.p999_us as f64)),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_resilience_smoke_writes_json() {
        let dir = std::env::temp_dir().join("sparsep_bench_resilience_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_resilience_test.json");
        let opts = ResilienceBenchOpts {
            rows: 300,
            deg: 4,
            requests: 3,
            shards: 2,
            dpus_per_shard: 4,
            threads: 2,
            samples: 1,
            max_queue: 2,
            offered: 5,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("resilience_tier"));
        assert!(j.get("respawns").as_f64().unwrap() >= 1.0);
        assert_eq!(j.get("served").as_f64(), Some(2.0));
        assert_eq!(j.get("shed").as_f64(), Some(3.0));
        assert!(j.get("recovery_overhead_x").as_f64().unwrap() > 0.0);
        std::fs::remove_file(&out).ok();
    }
}
