//! COO DPU kernel.
//!
//! COO carries an explicit row index per non-zero, so tasklet work can be
//! divided three ways (the paper's `COO.row`, `COO.nnz-rgrn`, `COO.nnz`):
//!
//! * `Rows` — contiguous row ranges (lock-free, like CSR);
//! * `Nnz` — equal non-zeros at *row granularity* (lock-free);
//! * `NnzElement` — equal non-zeros at *element granularity*: the split
//!   may fall inside a row, so the boundary rows are shared between
//!   neighbouring tasklets and their accumulations must synchronize.
//!   This is where the paper's three synchronization schemes (lock-free
//!   private accumulators + merge, coarse mutex, fine-grained mutex
//!   array) differ — and where real UPMEM hardware makes fine == coarse
//!   because critical-section MRAM accesses serialize.

use super::{acct, DpuKernelOutput, SyncScheme, TaskletBalance};
use crate::matrix::{CooMatrix, SpElem};
use crate::partition::balance::{split_elements, split_even, split_weighted};
use crate::pim::{PimConfig, TaskletCounters};

/// Run the COO kernel on one DPU. See module docs for the balancing /
/// synchronization semantics.
pub fn run_coo_dpu<T: SpElem>(
    cfg: &PimConfig,
    slice: &CooMatrix<T>,
    x: &[T],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    run_coo_dpu_cached(cfg, slice, x, &coo_split(slice, cfg.tasklets, bal), bal, sync)
}

/// [`run_coo_dpu`] with a precomputed [`CooSplit`] — the plan-time-split
/// entry point: the execution plan caches the split per work item, so
/// repeated invocations skip the O(nnz) row-count pass and the
/// shared-boundary-row scan. `split` must have been computed for
/// `cfg.tasklets` tasklets under the same `bal`.
pub fn run_coo_dpu_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &CooMatrix<T>,
    x: &[T],
    split: &CooSplit,
    bal: TaskletBalance,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let mut y = vec![T::zero(); slice.nrows()];
    let mut counters = vec![TaskletCounters::default(); t];

    let elem_ranges = &split.elem_ranges;
    let shared = &split.shared;

    for (tid, range) in elem_ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared.bounds[tid];
        // Stream this tasklet's (row, col, val) triples MRAM->WRAM.
        acct::stream_matrix(c, range.len() * (8 + dt.size_bytes()));
        let mut current_row = u32::MAX;
        let mut rows_here = 0usize;
        for i in range.clone() {
            let (r, col, v) = (slice.rows[i], slice.cols[i] as usize, slice.vals[i]);
            if r != current_row {
                // Row transition: close previous accumulator, open new.
                acct::row(c);
                current_row = r;
                rows_here += 1;
            }
            acct::element(c, dt);
            let contrib = v.mul(x[col]);
            if r == shared_head || r == shared_tail {
                acct::locked_update(c, dt, sync);
            }
            y[r as usize] = y[r as usize].add(contrib);
        }
        acct::writeback(c, rows_here, dt);
    }

    // Lock-free element-granularity: merge epilogue on tasklet 0.
    if bal == TaskletBalance::NnzElement && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, shared.n_shared, dt);
    }

    DpuKernelOutput::finish(cfg, y, counters)
}

/// Plan-time per-tasklet split for the COO kernel: the element ranges
/// plus the shared-boundary-row metadata for one tasklet count under
/// one balancing scheme. Computing it costs an O(nnz) row-count pass
/// (row-granularity schemes) plus the boundary scan, which is why the
/// execution plan caches one per work item.
#[derive(Clone, Debug)]
pub struct CooSplit {
    /// Tasklet count the ranges were computed for.
    pub(crate) tasklets: usize,
    pub(crate) elem_ranges: Vec<std::ops::Range<usize>>,
    pub(crate) shared: SharedRows,
}

/// Compute the per-tasklet element split — shared by the single-vector
/// and batched entry points (and cached at plan time) so every walk
/// splits identically.
pub fn coo_split<T: SpElem>(slice: &CooMatrix<T>, t: usize, bal: TaskletBalance) -> CooSplit {
    let elem_ranges = tasklet_elem_ranges(slice, t, bal);
    let shared = shared_boundary_rows(slice, &elem_ranges, bal);
    CooSplit { tasklets: t, elem_ranges, shared }
}

/// Per-tasklet element ranges for the COO balancing schemes — shared by
/// the single-vector and batched entry points so they split identically.
fn tasklet_elem_ranges<T: SpElem>(
    slice: &CooMatrix<T>,
    t: usize,
    bal: TaskletBalance,
) -> Vec<std::ops::Range<usize>> {
    // Row-granularity schemes map row chunks back to element ranges
    // (rows are contiguous in canonical COO order).
    let row_start_elem = |slice: &CooMatrix<T>| {
        let mut start = vec![0usize; slice.nrows() + 1];
        for &r in &slice.rows {
            start[r as usize + 1] += 1;
        }
        for r in 0..slice.nrows() {
            start[r + 1] += start[r];
        }
        start
    };
    match bal {
        TaskletBalance::NnzElement => split_elements(slice.nnz(), t),
        TaskletBalance::Nnz => {
            let weights = slice.row_counts();
            let row_chunks = split_weighted(&weights, t);
            let start = row_start_elem(slice);
            row_chunks.iter().map(|rc| start[rc.start]..start[rc.end]).collect()
        }
        TaskletBalance::Rows => {
            let row_chunks = split_even(slice.nrows(), t);
            let start = row_start_elem(slice);
            row_chunks.iter().map(|rc| start[rc.start]..start[rc.end]).collect()
        }
        TaskletBalance::Blocks => panic!("COO kernel does not support block balancing"),
    }
}

/// Rows shared by more than one tasklet, per tasklet.
#[derive(Clone, Debug)]
pub(crate) struct SharedRows {
    /// Distinct shared rows (lock-free merge epilogue size).
    n_shared: usize,
    /// Per tasklet: (head row shared with the previous range, tail row
    /// shared with the next), `u32::MAX` when unshared.
    bounds: Vec<(u32, u32)>,
}

/// Which rows are shared by more than one tasklet? Only the rows at
/// contiguous range boundaries can be (element-granularity splits), so
/// a per-element membership test reduces to at most two integer
/// compares — no hash probes in the inner loop (§Perf iteration 3).
fn shared_boundary_rows<T: SpElem>(
    slice: &CooMatrix<T>,
    elem_ranges: &[std::ops::Range<usize>],
    bal: TaskletBalance,
) -> SharedRows {
    let nnz = slice.nnz();
    let mut n_shared = 0usize;
    let mut bounds: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); elem_ranges.len()];
    if bal == TaskletBalance::NnzElement {
        let mut last_shared = u32::MAX;
        for i in 0..elem_ranges.len().saturating_sub(1) {
            let (a, b) = (&elem_ranges[i], &elem_ranges[i + 1]);
            if a.end > a.start && b.end > b.start && a.end < nnz {
                let boundary_row = slice.rows[a.end - 1];
                if boundary_row == slice.rows[b.start] {
                    // Boundary rows are non-decreasing: dedup against the
                    // previous one (a hot row can span many ranges).
                    if boundary_row != last_shared {
                        n_shared += 1;
                        last_shared = boundary_row;
                    }
                    bounds[i].1 = boundary_row; // tail of range i
                    bounds[i + 1].0 = boundary_row; // head of i+1
                }
            }
        }
    }
    SharedRows { n_shared, bounds }
}

/// Run the COO kernel on one DPU for a whole block of input vectors.
///
/// Fused SpMM-style variant of [`run_coo_dpu`]: one pass over the
/// (row, col, val) triples updates every vector's output, so the
/// host-side simulation streams the slice (and runs the cycle
/// accounting) once per *block* instead of once per *vector*. Results
/// are bit-identical to calling [`run_coo_dpu`] once per vector — the
/// per-vector accumulation order is unchanged and the accounting is
/// structure-only (see `finish_batch` in the module root).
///
/// The tasklet walk below deliberately mirrors [`run_coo_dpu`]'s (a
/// shared walk would put a per-element vector loop on the single-vector
/// hot path): any change to the accounting sequence there must be
/// mirrored here, and `tests/batch_equivalence.rs` fails on any drift.
pub fn run_coo_dpu_batch<T: SpElem>(
    cfg: &PimConfig,
    slice: &CooMatrix<T>,
    xs: &[&[T]],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    run_coo_dpu_batch_cached(cfg, slice, xs, &coo_split(slice, cfg.tasklets, bal), bal, sync)
}

/// [`run_coo_dpu_batch`] with a precomputed [`CooSplit`] (see
/// [`run_coo_dpu_cached`]).
pub fn run_coo_dpu_batch_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &CooMatrix<T>,
    xs: &[&[T]],
    split: &CooSplit,
    bal: TaskletBalance,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    if xs.is_empty() {
        return Vec::new();
    }
    if xs.len() == 1 {
        return vec![run_coo_dpu_cached(cfg, slice, xs[0], split, bal, sync)];
    }
    for x in xs {
        assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    }
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let mut ys: Vec<Vec<T>> = (0..xs.len()).map(|_| vec![T::zero(); slice.nrows()]).collect();
    let mut counters = vec![TaskletCounters::default(); t];

    let elem_ranges = &split.elem_ranges;
    let shared = &split.shared;

    for (tid, range) in elem_ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared.bounds[tid];
        acct::stream_matrix(c, range.len() * (8 + dt.size_bytes()));
        let mut current_row = u32::MAX;
        let mut rows_here = 0usize;
        for i in range.clone() {
            let (r, col, v) = (slice.rows[i], slice.cols[i] as usize, slice.vals[i]);
            if r != current_row {
                acct::row(c);
                current_row = r;
                rows_here += 1;
            }
            acct::element(c, dt);
            if r == shared_head || r == shared_tail {
                acct::locked_update(c, dt, sync);
            }
            let ri = r as usize;
            for (b, y) in ys.iter_mut().enumerate() {
                y[ri] = y[ri].add(v.mul(xs[b][col]));
            }
        }
        acct::writeback(c, rows_here, dt);
    }

    if bal == TaskletBalance::NnzElement && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, shared.n_shared, dt);
    }

    super::finish_batch(cfg, ys, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    fn cfg(t: usize) -> PimConfig {
        PimConfig { tasklets: t, ..Default::default() }
    }

    fn check(m: &CooMatrix<f64>, t: usize, bal: TaskletBalance, sync: SyncScheme) {
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 11) as f64) - 5.0).collect();
        let out = run_coo_dpu(&cfg(t), m, &x, bal, sync);
        assert_eq!(out.y, m.spmv(&x), "t={t} bal={bal:?} sync={sync:?}");
    }

    #[test]
    fn correct_across_all_schemes() {
        let m = generate::scale_free::<f64>(400, 400, 7, 0.6, 11);
        for t in [1, 3, 16] {
            for bal in [TaskletBalance::Rows, TaskletBalance::Nnz, TaskletBalance::NnzElement] {
                for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                    check(&m, t, bal, sync);
                }
            }
        }
    }

    #[test]
    fn correct_on_single_dense_row() {
        // Everything in one row: element split shares it among all.
        let triples: Vec<(u32, u32, f64)> =
            (0..64).map(|c| (0u32, c as u32, 1.0 + c as f64)).collect();
        let m = CooMatrix::from_triples(1, 64, triples);
        check(&m, 16, TaskletBalance::NnzElement, SyncScheme::CoarseLock);
        check(&m, 16, TaskletBalance::NnzElement, SyncScheme::LockFree);
    }

    #[test]
    fn element_split_beats_row_split_on_skew() {
        // Element-granularity split fixes even a single mega-row.
        let mut triples: Vec<(u32, u32, f64)> =
            (0..2000).map(|c| (0u32, c % 500, 1.0)).collect();
        for r in 1..100u32 {
            triples.push((r, 0, 1.0));
        }
        let m = CooMatrix::from_triples(100, 500, triples);
        let x = vec![1.0; 500];
        let c = cfg(16);
        let row = run_coo_dpu(&c, &m, &x, TaskletBalance::Rows, SyncScheme::LockFree);
        let elem = run_coo_dpu(&c, &m, &x, TaskletBalance::NnzElement, SyncScheme::LockFree);
        assert!(
            elem.timing.cycles < row.timing.cycles / 2,
            "elem {} !<< row {}",
            elem.timing.cycles,
            row.timing.cycles
        );
    }

    #[test]
    fn fine_lock_not_faster_than_coarse() {
        // The paper's hardware finding: fine-grained locking does not
        // improve over coarse because critical sections serialize on the
        // DPU's shared DMA/WRAM path.
        let triples: Vec<(u32, u32, f64)> =
            (0..4096).map(|i| ((i / 512) as u32, (i % 512) as u32, 1.0)).collect();
        let m = CooMatrix::from_triples(8, 512, triples);
        let x = vec![1.0; 512];
        let c = cfg(16);
        let coarse = run_coo_dpu(&c, &m, &x, TaskletBalance::NnzElement, SyncScheme::CoarseLock);
        let fine = run_coo_dpu(&c, &m, &x, TaskletBalance::NnzElement, SyncScheme::FineLock);
        assert!(
            fine.timing.cycles >= coarse.timing.cycles,
            "fine {} should not beat coarse {}",
            fine.timing.cycles,
            coarse.timing.cycles
        );
    }

    #[test]
    fn empty_matrix_ok() {
        let m = CooMatrix::<f64>::zeros(8, 8);
        check(&m, 4, TaskletBalance::NnzElement, SyncScheme::LockFree);
    }

    #[test]
    fn batch_matches_looped_single_vector() {
        let m = generate::scale_free::<f64>(300, 300, 7, 0.7, 23);
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|b| (0..300).map(|i| ((i + 5 * b) % 11) as f64 - 5.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        for bal in [TaskletBalance::Rows, TaskletBalance::Nnz, TaskletBalance::NnzElement] {
            for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                let batch = run_coo_dpu_batch(&cfg(16), &m, &refs, bal, sync);
                assert_eq!(batch.len(), xs.len());
                for (x, out) in xs.iter().zip(&batch) {
                    let single = run_coo_dpu(&cfg(16), &m, x, bal, sync);
                    assert_eq!(out.y, single.y, "{bal:?} {sync:?}: y differs");
                    assert_eq!(out.counters, single.counters, "{bal:?} {sync:?}: counters differ");
                    assert_eq!(out.timing, single.timing, "{bal:?} {sync:?}: timing differs");
                }
            }
        }
        assert!(run_coo_dpu_batch(&cfg(4), &m, &[], TaskletBalance::NnzElement, SyncScheme::LockFree)
            .is_empty());
    }
}
