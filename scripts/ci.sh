#!/usr/bin/env bash
# Tier-1 gate + release examples: what every PR must keep green.
#
#   scripts/ci.sh            # build + test + examples
#   SKIP_EXAMPLES=1 scripts/ci.sh   # tier-1 only
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# The sharding/differential suites (incl. the deterministic fairness
# tests, `fairness_*` in shard_equivalence) are the PR-4 acceptance
# gates. They already ran inside the unfiltered tier-1 above; the named
# re-run is deliberate redundancy so the gate stays visible and cannot
# be lost to a future filtered/partial tier-1 invocation. Both suites
# are seconds-scale (tiny matrices).
echo "== sharding: differential + shard-planning + fairness suites =="
cargo test -q --test shard_equivalence
cargo test -q --test proptest_shard

# 2D grid + replication gates (PR 10): the grid differential suite
# proves every R x C grid shape and replica count answers bit-identically
# to the unsharded oracle (reduction gather in fixed ascending-column
# order), that R x 1 grids are byte-identical to the legacy row-sharded
# responses (metrics included), that seeded chaos replays identically on
# grid coordinates, and that losing a replica mid-flight recovers with
# zero new plan builds. The grid property tests (tile partition,
# reduced-gather oracle, replica-kill recovery) ride in proptest_shard
# above.
echo "== grid: 2D sharding + replication differential suite =="
cargo test -q --test grid_equivalence

# Hot-path gates (PR 5): the engine-equivalence suite now covers the
# persistent PooledEngine next to the legacy spawn-per-wave threading,
# and the zero-copy suite locks the Arc payload sharing (pointer
# identity across the sharded scatter, paused-scheduler reference
# counting, iterate feedback re-wrap). Same deliberate redundancy.
echo "== hot path: engine equivalence (pooled + spawning) + zero-copy payloads =="
cargo test -q --test engine_equivalence
cargo test -q --test zero_copy

# Autotuner gates (PR 6): the calibration suite locks table round-trip
# + checksum rejection + deterministic ties + calibrated-specs-always-
# plan + the calibrated/uncalibrated differential; the quick tune run
# is the perf gate — the heuristic configuration is measured as
# candidate zero of the same sweep, so calibrated winners are >= 1.0x
# by construction and the harness fails (in-process --tolerance check)
# if any (matrix, batch) cell regresses.
echo "== autotuner: calibration suite + quick search gate =="
cargo test -q --test calibration
cargo run --release -- tune --quick --out calibration.json --report BENCH_tune.json

# Bench regression gate (PR 10): compare the bench reports this run
# produced against the committed baseline of by-construction ratio
# statistics (scripts/bench_baseline.json). CI only runs the quick tune
# above, so absent BENCH_*.json files are skipped — bench_smoke.sh runs
# the same gate with --missing fail after producing every report.
echo "== bench-check: regression gate vs scripts/bench_baseline.json =="
cargo run --release -- bench-check \
  --baseline scripts/bench_baseline.json \
  --missing skip

# Resilience gates (PR 7): the chaos suite drives every fault scenario
# (kill-at-dispatch / kill-at-gather / dropped completion / delayed
# stage) across all request shapes, both engines and shard counts
# {1,2,3,5}, asserting the gathered outputs stay bit-identical to the
# fault-free oracle, that seeded fault plans replay exactly, that
# floods shed as typed Overloaded without starving other tenants, and
# that a stalled shard times out naming itself. Same deliberate
# redundancy: it already ran in the unfiltered tier-1 above, but the
# named re-run keeps the gate visible.
echo "== resilience: chaos equivalence suite =="
cargo test -q --test chaos_equivalence

# Network front-end gates (PR 9): the net differential suite proves
# that responses received over a real TCP connection are bit-identical
# — values, breakdowns, stats, energy — to an identically-configured
# in-process facade (all request shapes, both engines, shard counts
# {1,2,4}, two tenants), that seeded chaos replays identically on both
# sides of the wire, and that typed Overloaded / ShardTimeout outcomes
# survive the transport. The in-crate net unit tests (protocol
# round-trip + decoder fuzz + server/client behavior + the loadgen
# smoke) already ran in the unfiltered tier-1 above; the named re-runs
# keep the gates visible.
echo "== net: wire-protocol + server unit suites =="
cargo test -q --lib net::
echo "== net: TCP differential equivalence suite =="
cargo test -q --test net_equivalence

echo "== lint: cargo clippy --all-targets (warnings are errors) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy component unavailable; skipping lint gate"
fi

# Concurrency verification gates (PR 8): the clippy facade wall (raw
# std::sync primitives / raw spawns outside util::sync are
# disallowed-types, proven live by a canary that must FAIL the lint),
# the loom model suite over the wave / completion / recycle / respawn
# protocols, the Miri slice over the TaskPtr unsafe code, and a TSan
# pass. Each sub-gate is toolchain-guarded exactly like the clippy gate
# above, so this stays runnable in the offline build container.
echo "== analyze: concurrency verification gates (scripts/analyze.sh) =="
scripts/analyze.sh

echo "== docs: cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [[ "${SKIP_EXAMPLES:-0}" != "1" ]]; then
  for ex in quickstart format_explorer scaling_study e2e_characterization; do
    echo "== example: $ex (release) =="
    cargo run --release --example "$ex"
  done
fi

echo "CI OK"
