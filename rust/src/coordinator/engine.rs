//! Execution engines: how per-DPU kernel simulations are driven.
//!
//! A real UPMEM deployment launches all allocated DPUs at once and waits
//! for the slowest; the simulator used to walk them one by one in the
//! host thread, which made iterative apps and the figure drivers scale
//! with `n_dpus` in *wall-clock* even though the modeled system is
//! parallel. An [`ExecutionEngine`] closes that gap: it maps a pure
//! per-DPU function over the work items, either serially
//! ([`SerialEngine`]), on `std::thread` scoped threads spawned per wave
//! ([`ThreadedEngine`]), or on a persistent worker pool
//! ([`PooledEngine`] — the default behind [`Engine::threaded`]).
//!
//! The pooled engine exists because spawn/join is a per-*wave* cost:
//! iterative apps (CG / Jacobi / PageRank), the pipelined request
//! queue's kernel stage, and every `ShardedService` backend drive one
//! engine wave per iteration / vector block, so spawning fresh OS
//! threads each time puts thread creation on the host hot path — the
//! very orchestration overhead the PIM benchmarking literature warns
//! dominates kernel time on real systems. Pool workers are long-lived,
//! fed waves over a condvar-guarded queue, and shared process-wide (one
//! pool per worker count), so concurrent services feed the same
//! workers instead of oversubscribing the host.
//!
//! Engines only change *where* the per-item closures run. Results are
//! collected back in item order and every aggregation (output vector,
//! cycle maxima, energy sums) happens serially afterwards, so all the
//! engines are bit-identical by construction — a property the
//! `engine_equivalence` test suite locks in.
//!
//! The unit of work an engine schedules is whatever the caller indexes:
//! single-vector execution maps over work items (one per DPU slice),
//! and the batched path ([`super::ExecutionPlan::execute_batch_runs`])
//! maps over (work-item x vector-block) units — so a batch keeps every
//! worker busy even when the DPU count alone would not, with no engine
//! changes and the same by-index determinism (locked by the
//! `batch_equivalence` suite).
//!
//! [`super::SpmvService`]'s pipelined request engine layers on top: its
//! kernel stage drives one engine wave per vector block while separate
//! stage threads prepare the next block and merge the previous one, so
//! the engine choice composes with (rather than competes against)
//! request pipelining. The `service_equivalence` suite locks that the
//! composition stays bit-identical to synchronous execution.

/// Strategy for running independent per-DPU work items.
pub trait ExecutionEngine {
    /// Engine name for logs and JSON output.
    fn name(&self) -> &'static str;

    /// Apply `f` to every index in `0..n` and return the results in
    /// index order. `f` must be pure with respect to ordering: engines
    /// are free to evaluate indices concurrently and in any order.
    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync;
}

/// Runs every work item on the calling thread, in order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SerialEngine;

impl ExecutionEngine for SerialEngine {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        (0..n).map(f).collect()
    }
}

/// Runs work items on scoped OS threads (no external dependencies).
///
/// Workers pull item indices from a shared atomic counter (dynamic load
/// balancing — skewed per-DPU work cannot strand one worker with all
/// the heavy slices), and results are reassembled by index — completion
/// order never leaks into results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadedEngine {
    /// Worker count; 0 means "all available hardware threads".
    pub threads: usize,
}

impl ThreadedEngine {
    pub fn new(threads: usize) -> ThreadedEngine {
        ThreadedEngine { threads }
    }

    /// Resolved worker count (>= 1).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

impl Default for ThreadedEngine {
    fn default() -> ThreadedEngine {
        ThreadedEngine { threads: 0 }
    }
}

impl ExecutionEngine for ThreadedEngine {
    fn name(&self) -> &'static str {
        // Matches the engine's CLI/env identity (`--engine spawning`,
        // `SPARSEP_ENGINE=spawning`): "threaded" now names the pooled
        // default, and operator-facing output must not suggest the
        // pooled engine ran when the spawn-per-wave baseline did.
        "spawning"
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let workers = self.effective_threads().min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        // Dynamic work distribution: workers pull the next index from a
        // shared counter, so skewed per-item cost (a hot DPU slice on a
        // scale-free matrix) cannot gate wall-clock on one unlucky
        // worker. Each worker tags results with their index and the
        // reassembly below is by index — bit-deterministic regardless
        // of which worker ran what.
        let f = &f;
        let next = AtomicUsize::new(0);
        let next = &next;
        let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(move || {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                parts.push(h.join().expect("execution-engine worker panicked"));
            }
        });
        // Reassemble by index: flatten the per-worker parts (each already
        // ascending — workers pull from a monotonic counter) and sort into
        // a single pre-sized buffer, instead of the old Vec<Option<R>> +
        // unwrap pass that allocated and walked the output twice.
        let mut tagged: Vec<(usize, R)> = Vec::with_capacity(n);
        for part in parts {
            tagged.extend(part);
        }
        tagged.sort_unstable_by_key(|&(i, _)| i);
        debug_assert!(
            tagged.windows(2).all(|w| w[0].0 != w[1].0),
            "execution engine computed an index twice"
        );
        assert_eq!(tagged.len(), n, "execution engine missed an index");
        tagged.into_iter().map(|(_, r)| r).collect()
    }
}

/// Persistent worker-pool engine: long-lived workers fed waves of
/// indexed work over a condvar-guarded queue, with the same
/// atomic-counter dynamic load balancing as [`ThreadedEngine`] and the
/// same by-index reassembly — bit-identical results, locked by the
/// `engine_equivalence` suite.
///
/// Pools are process-wide, keyed by worker count: every engine value
/// with the same `threads` shares one set of workers, so the pipelined
/// request queue, iterative apps and all `ShardedService` backends feed
/// the same pool instead of each spawning (and joining) fresh OS
/// threads once per wave. The submitting thread also helps drain its
/// own wave, so small waves skip a context switch entirely and a wave
/// can never deadlock behind a busy pool. Workers park on a condvar
/// while idle and live for the process lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PooledEngine {
    /// Worker count; 0 means "all available hardware threads".
    pub threads: usize,
}

impl PooledEngine {
    pub fn new(threads: usize) -> PooledEngine {
        PooledEngine { threads }
    }

    /// Resolved worker count (>= 1).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

impl ExecutionEngine for PooledEngine {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = self.effective_threads();
        if workers <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        // One parking slot per index: each index is claimed by exactly
        // one thread (atomic counter) and written under its own
        // uncontended lock; collection below is by index, so which
        // worker ran what can never leak into results.
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let task = |i: usize| {
            let r = f(i);
            *slots[i].lock().expect("pool result slot poisoned") = Some(r);
        };
        pool::global(workers).run_wave(n, &task);
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("pool result slot poisoned")
                    .expect("pooled engine missed an index")
            })
            .collect()
    }
}

use crate::util::sync::Mutex;

/// The process-wide worker pools behind [`PooledEngine`].
///
/// `pub(crate)` (not private) so the `cfg(loom)` verification module
/// (`coordinator::verify`) can drive a *local* pool — spawned, drained,
/// shut down and joined inside one loom model iteration — through the
/// exact production wave protocol.
pub(crate) mod pool {
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use crate::util::sync::{thread, Arc, Condvar, Mutex};
    use std::collections::{HashMap, VecDeque};
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    use std::sync::OnceLock;

    /// Lifetime-erased pointer to a wave's per-index task. The submitter
    /// blocks inside [`WorkerPool::run_wave`] until every index of its
    /// wave has been computed and the wave is retired from the queue, so
    /// the pointee outlives every dereference: workers only touch the
    /// pointer after claiming a not-yet-completed index (which keeps the
    /// submitter blocked), and panics inside the task are caught in
    /// [`Wave::drain`] — no unwind can exit `run_wave` (or kill a
    /// worker) while the wave is still queued.
    #[derive(Clone, Copy)]
    struct TaskPtr {
        data: *const (),
        call: unsafe fn(*const (), usize),
    }

    unsafe impl Send for TaskPtr {}
    unsafe impl Sync for TaskPtr {}

    /// # Safety
    ///
    /// `data` must be the erasure of a live `&F` (produced by
    /// [`WorkerPool::run_wave`]) and must stay live for the whole call.
    /// The wave protocol guarantees it: the submitter that owns the
    /// closure blocks in `run_wave` until every claimed index has
    /// completed, and no thread calls through a [`TaskPtr`] without
    /// first claiming a not-yet-completed index.
    unsafe fn call_task<F: Fn(usize) + Sync>(data: *const (), i: usize) {
        // SAFETY: `data` was erased from `&F` in `run_wave` (same `F`:
        // the function pointer is monomorphized alongside the erasure),
        // and the caller guarantees the pointee is still live, so the
        // cast restores the original shared reference.
        let f = unsafe { &*(data as *const F) };
        f(i)
    }

    /// One wave of `n` indexed work items shared between the submitting
    /// thread and the pool workers.
    struct Wave {
        task: TaskPtr,
        n: usize,
        /// Next index to claim (dynamic load balancing: skewed per-item
        /// cost cannot strand one thread with all the heavy items).
        next: AtomicUsize,
        /// Indices fully computed; the wave is done at `n`.
        completed: AtomicUsize,
        done: Mutex<bool>,
        done_cv: Condvar,
        /// First panic payload captured from the task closure, re-raised
        /// on the submitting thread after the wave completes — the
        /// pooled analogue of the spawn-per-wave engine's
        /// `join().expect(...)` propagation.
        panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    }

    impl Wave {
        /// Claim and compute indices until the counter is exhausted.
        /// Run by pool workers and by the submitting thread alike.
        fn drain(&self) {
            loop {
                let i = self.next.fetch_add(1, Ordering::Relaxed);
                if i >= self.n {
                    return;
                }
                // The claimed index is not yet completed, so the
                // submitter is still blocked and the task pointer valid.
                //
                // Panics must not escape: a dying pool worker would
                // strand the submitter (completed never reaches n), and
                // a submitter unwinding out of its own drain would leave
                // a dangling task pointer queued. Catch, record, count
                // the index as completed, and let the submitter re-raise
                // once the wave is retired. (AssertUnwindSafe: a
                // panicked index leaves its result slot unwritten, but
                // the submitter re-raises before reading any slot, so a
                // broken invariant is never observed.)
                // SAFETY: this thread just claimed index `i` and has
                // not yet counted it completed, so the submitter is
                // still blocked in `run_wave` and the erased closure
                // behind `task.data` is live; `task.call` was
                // monomorphized for the same closure type at erasure.
                let outcome = catch_unwind(AssertUnwindSafe(|| unsafe {
                    (self.task.call)(self.task.data, i)
                }));
                if let Err(payload) = outcome {
                    let mut first = self.panic.lock().expect("wave panic slot poisoned");
                    if first.is_none() {
                        *first = Some(payload);
                    }
                }
                // AcqRel chains every worker's writes into the release
                // sequence the final increment publishes, so the
                // submitter (synchronizing through `done`) observes all
                // result slots.
                if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                    *self.done.lock().expect("wave done flag poisoned") = true;
                    self.done_cv.notify_all();
                }
            }
        }
    }

    /// The wave queue plus the shutdown flag, under one lock.
    struct PoolQueue {
        waves: VecDeque<Arc<Wave>>,
        /// Set by [`WorkerPool::shutdown`]: workers keep serving waves
        /// with unclaimed indices, and exit (instead of parking) once
        /// none remain. The process-wide pools never set this; local
        /// pools (unit tests, Miri, the loom models) must, so every
        /// worker thread terminates and can be joined.
        shutdown: bool,
    }

    /// A set of persistent workers plus the queue of in-flight waves.
    /// Multiple waves may be in flight at once (concurrent services);
    /// workers always serve the oldest wave that still has unclaimed
    /// indices.
    pub(crate) struct WorkerPool {
        queue: Mutex<PoolQueue>,
        work_ready: Condvar,
    }

    impl WorkerPool {
        /// Build a pool and spawn its `workers` threads, returning the
        /// pool plus the workers' join handles. [`global`] drops the
        /// handles (process-lifetime pools are never torn down); local
        /// pools keep them and join after [`WorkerPool::shutdown`].
        pub(crate) fn with_workers(
            workers: usize,
        ) -> (Arc<WorkerPool>, Vec<thread::JoinHandle<()>>) {
            let pool = Arc::new(WorkerPool {
                queue: Mutex::new(PoolQueue { waves: VecDeque::new(), shutdown: false }),
                work_ready: Condvar::new(),
            });
            let handles = (0..workers)
                .map(|k| {
                    let p = Arc::clone(&pool);
                    thread::spawn_named(&format!("sparsep-pool{workers}-w{k}"), move || {
                        p.worker_loop()
                    })
                })
                .collect();
            (pool, handles)
        }

        /// Ask every worker to exit once no queued wave has unclaimed
        /// indices. In-flight waves still complete: `run_wave` helps
        /// drain and never depends on any worker existing.
        #[cfg_attr(not(test), allow(dead_code))] // unit tests, Miri and the cfg(loom) models
        pub(crate) fn shutdown(&self) {
            self.queue.lock().expect("pool queue poisoned").shutdown = true;
            self.work_ready.notify_all();
        }

        fn worker_loop(&self) {
            loop {
                let wave = {
                    let mut q = self.queue.lock().expect("pool queue poisoned");
                    loop {
                        if let Some(w) =
                            q.waves.iter().find(|w| w.next.load(Ordering::Relaxed) < w.n)
                        {
                            break Some(Arc::clone(w));
                        }
                        if q.shutdown {
                            break None;
                        }
                        q = self.work_ready.wait(q).expect("pool queue poisoned");
                    }
                };
                match wave {
                    Some(wave) => wave.drain(),
                    None => return,
                }
            }
        }

        /// Publish one wave, help drain it, and block until every index
        /// has been computed. On return no thread holds the task pointer.
        pub(crate) fn run_wave<F: Fn(usize) + Sync>(&self, n: usize, task: &F) {
            debug_assert!(n > 0);
            let wave = Arc::new(Wave {
                task: TaskPtr { data: task as *const F as *const (), call: call_task::<F> },
                n,
                next: AtomicUsize::new(0),
                completed: AtomicUsize::new(0),
                done: Mutex::new(false),
                done_cv: Condvar::new(),
                panic: Mutex::new(None),
            });
            self.queue.lock().expect("pool queue poisoned").waves.push_back(Arc::clone(&wave));
            self.work_ready.notify_all();
            // Help drain our own wave: a small wave finishes on this
            // thread without a context switch, and even a fully busy
            // pool cannot deadlock a submitter.
            wave.drain();
            // Wait for stragglers still computing their last claimed
            // index on other workers.
            let mut done = wave.done.lock().expect("wave done flag poisoned");
            while !*done {
                done = wave.done_cv.wait(done).expect("wave done flag poisoned");
            }
            drop(done);
            // Retire the wave: after run_wave returns (or unwinds via
            // the re-raise below), the caller's task closure is dead, so
            // it must leave the queue with it. (Workers that still hold
            // an Arc see an exhausted counter and never touch the task
            // pointer again.)
            {
                let mut q = self.queue.lock().expect("pool queue poisoned");
                if let Some(pos) = q.waves.iter().position(|w| Arc::ptr_eq(w, &wave)) {
                    q.waves.remove(pos);
                }
            }
            // A task panicked (on whichever thread ran it): re-raise on
            // the submitter, exactly like the spawn-per-wave engine's
            // `join().expect(...)` would have. The wave is already
            // retired, so the unwind is safe.
            if let Some(payload) = wave.panic.lock().expect("wave panic slot poisoned").take() {
                resume_unwind(payload);
            }
        }
    }

    /// The process-wide pool for `workers` workers, created on first
    /// use. Pools are never torn down — idle workers cost a parked
    /// thread each, and sharing them is exactly what keeps thread
    /// spawn/join off the per-wave hot path.
    ///
    /// The registry lock only guards the map; pool *construction* —
    /// worker spawning, which can fail under thread-limit pressure —
    /// runs outside it through a per-size once-cell. A failed spawn
    /// therefore panics only the calling wave (and is retried on the
    /// next call: a panicking `get_or_init` leaves the cell empty)
    /// instead of poisoning the registry for every future wave in the
    /// process.
    pub(super) fn global(workers: usize) -> Arc<WorkerPool> {
        type Registry = Mutex<HashMap<usize, Arc<OnceLock<Arc<WorkerPool>>>>>;
        static POOLS: OnceLock<Registry> = OnceLock::new();
        let registry = POOLS.get_or_init(|| Mutex::new(HashMap::new()));
        let cell = {
            let mut map = registry.lock().expect("pool registry poisoned");
            Arc::clone(map.entry(workers).or_default())
        };
        Arc::clone(cell.get_or_init(|| {
            // Process-lifetime pool: the worker handles are dropped
            // (detached) — these workers are deliberately never joined.
            WorkerPool::with_workers(workers).0
        }))
    }
}

/// Runtime-selectable engine (what [`super::SpmvExecutor`] carries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Serial,
    /// Legacy spawn-per-wave threading (kept for the hot-path benches;
    /// [`Engine::threaded`] now builds the pooled engine instead).
    Threaded(ThreadedEngine),
    /// Persistent worker pool — the threaded default.
    Pooled(PooledEngine),
}

impl Engine {
    /// Threaded engine with `threads` workers (0 = all hardware
    /// threads). Since the hot-path overhaul this is the *pooled*
    /// engine: waves run on persistent workers instead of paying thread
    /// spawn/join per wave. Results are bit-identical either way
    /// (`engine_equivalence`); use [`Engine::spawning`] for the legacy
    /// spawn-per-wave behavior.
    pub fn threaded(threads: usize) -> Engine {
        Engine::Pooled(PooledEngine::new(threads))
    }

    /// Legacy spawn-per-wave threaded engine (what [`Engine::threaded`]
    /// used to build) — the old-vs-new baseline of `bench-hotpath`.
    pub fn spawning(threads: usize) -> Engine {
        Engine::Threaded(ThreadedEngine::new(threads))
    }

    /// Engine selection from the environment: `SPARSEP_ENGINE`
    /// (`serial` | `threaded`/`pooled` | `spawning`, default serial) and
    /// `SPARSEP_THREADS` (worker count, default all cores). This is how
    /// the CLI's `--engine` / `--threads` flags reach code that builds
    /// its own executors (the bench-harness figure drivers call this
    /// explicitly; `SpmvExecutor::new` itself stays deterministic and
    /// defaults to serial).
    pub fn from_env() -> Engine {
        let engine = std::env::var("SPARSEP_ENGINE").ok();
        let threads = std::env::var("SPARSEP_THREADS").ok();
        Engine::resolve(engine.as_deref(), threads.as_deref())
    }

    /// The resolution (and warning) logic behind [`Engine::from_env`],
    /// split out over plain values so the error paths are unit-testable
    /// without mutating the process environment (`set_var` races other
    /// test threads reading it).
    fn resolve(engine: Option<&str>, threads: Option<&str>) -> Engine {
        let threads = match threads {
            None => 0,
            Some(v) => match v.trim().parse::<usize>() {
                Ok(n) => n,
                Err(_) => {
                    eprintln!(
                        "warning: unparseable SPARSEP_THREADS={v:?} (expected a worker count); using all cores"
                    );
                    0
                }
            },
        };
        match engine {
            Some("threaded") | Some("pooled") => Engine::threaded(threads),
            Some("spawning") => Engine::spawning(threads),
            Some("serial") | None => Engine::Serial,
            Some(other) => {
                eprintln!(
                    "warning: unrecognized SPARSEP_ENGINE={other:?} (expected serial|threaded|pooled|spawning); using serial"
                );
                Engine::Serial
            }
        }
    }

    /// Publish this engine choice to the environment (see
    /// [`Engine::from_env`]). Call before spawning any threads
    /// (`std::env::set_var` is not thread-safe); the CLI does this once
    /// at startup, before the first executor exists.
    pub fn export_env(&self) {
        match self {
            Engine::Serial => std::env::set_var("SPARSEP_ENGINE", "serial"),
            Engine::Threaded(t) => {
                std::env::set_var("SPARSEP_ENGINE", "spawning");
                std::env::set_var("SPARSEP_THREADS", t.threads.to_string());
            }
            Engine::Pooled(p) => {
                std::env::set_var("SPARSEP_ENGINE", "threaded");
                std::env::set_var("SPARSEP_THREADS", p.threads.to_string());
            }
        }
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::Serial
    }
}

impl ExecutionEngine for Engine {
    fn name(&self) -> &'static str {
        match self {
            Engine::Serial => SerialEngine.name(),
            Engine::Threaded(t) => t.name(),
            Engine::Pooled(p) => p.name(),
        }
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        match self {
            Engine::Serial => SerialEngine.map_indexed(n, f),
            Engine::Threaded(t) => t.map_indexed(n, f),
            Engine::Pooled(p) => p.map_indexed(n, f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_preserves_order() {
        let v = SerialEngine.map_indexed(5, |i| i * 2);
        assert_eq!(v, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn threaded_matches_serial_for_any_thread_count() {
        let work = |i: usize| (i, i * i + 1);
        let want = SerialEngine.map_indexed(97, work);
        for t in [1usize, 2, 3, 8, 64, 200] {
            let got = ThreadedEngine::new(t).map_indexed(97, work);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn threaded_handles_empty_and_single() {
        assert_eq!(ThreadedEngine::new(4).map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(ThreadedEngine::new(4).map_indexed(1, |i| i + 9), vec![9]);
    }

    #[test]
    fn threaded_actually_uses_multiple_threads() {
        use crate::util::sync::Mutex;
        use std::collections::HashSet;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        // Per-item work must be slow enough that one worker cannot
        // drain the whole range before the others are even scheduled
        // (threads take tens of microseconds to spawn).
        ThreadedEngine::new(4).map_indexed(64, |i| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_micros(500));
            i
        });
        assert!(ids.lock().unwrap().len() > 1, "expected >1 worker thread");
    }

    #[test]
    fn engine_enum_delegates() {
        assert_eq!(Engine::Serial.name(), "serial");
        assert_eq!(Engine::threaded(2).name(), "pooled", "threaded default is the pool");
        assert_eq!(Engine::spawning(2).name(), "spawning", "legacy engine owns its CLI name");
        assert_eq!(
            Engine::threaded(3).map_indexed(10, |i| i),
            Engine::Serial.map_indexed(10, |i| i)
        );
        assert_eq!(
            Engine::spawning(3).map_indexed(10, |i| i),
            Engine::Serial.map_indexed(10, |i| i)
        );
    }

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(ThreadedEngine::new(0).effective_threads() >= 1);
        assert_eq!(ThreadedEngine::new(6).effective_threads(), 6);
        assert!(PooledEngine::new(0).effective_threads() >= 1);
        assert_eq!(PooledEngine::new(6).effective_threads(), 6);
    }

    #[test]
    fn pooled_matches_serial_for_any_worker_count() {
        let work = |i: usize| (i, i * 31 + 7);
        let want = SerialEngine.map_indexed(113, work);
        for t in [1usize, 2, 3, 8, 64] {
            let got = PooledEngine::new(t).map_indexed(113, work);
            assert_eq!(got, want, "workers={t}");
        }
    }

    #[test]
    fn pooled_handles_empty_and_single() {
        assert_eq!(PooledEngine::new(4).map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(PooledEngine::new(4).map_indexed(1, |i| i + 9), vec![9]);
    }

    #[test]
    fn pooled_reuses_workers_across_waves() {
        use crate::util::sync::Mutex;
        use std::collections::HashSet;
        // Several waves on one engine: the union of worker threads ever
        // seen is capped at the pool size, where spawn-per-wave
        // threading would mint fresh threads every wave. (A union bound
        // is scheduling-independent — even an unlucky scheduler can
        // only ever pick subsets of the same persistent workers; an
        // intersection-style assertion would flake on loaded CI.)
        let me = std::thread::current().id();
        let engine = PooledEngine::new(4);
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        for _ in 0..3 {
            engine.map_indexed(64, |i| {
                ids.lock().unwrap().insert(std::thread::current().id());
                std::thread::sleep(std::time::Duration::from_micros(300));
                i
            });
        }
        let mut ids = ids.into_inner().unwrap();
        ids.remove(&me); // the submitter helps drain its own waves
        assert!(!ids.is_empty(), "expected pool workers to participate");
        assert!(
            ids.len() <= 4,
            "3 waves on a 4-worker pool saw {} distinct worker threads — workers did not persist",
            ids.len()
        );
    }

    #[test]
    fn pooled_propagates_task_panics_and_pool_survives() {
        // A panicking task must reach the submitter (like the
        // spawn-per-wave engine's join().expect) — not strand it on the
        // done condvar or kill a pool worker.
        let outcome = std::panic::catch_unwind(|| {
            PooledEngine::new(3).map_indexed(32, |i| {
                assert!(i != 17, "injected task failure");
                i
            })
        });
        assert!(outcome.is_err(), "a task panic must propagate to the submitter");
        // The pool is intact afterwards: the same workers serve the
        // next wave to completion.
        let got = PooledEngine::new(3).map_indexed(16, |i| i + 1);
        assert_eq!(got, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn pooled_concurrent_waves_do_not_cross_talk() {
        // Several submitters share one pool at once; every wave must
        // come back complete and in index order.
        std::thread::scope(|s| {
            for k in 0..4usize {
                s.spawn(move || {
                    let got = PooledEngine::new(3).map_indexed(200, move |i| i * 7 + k);
                    let want: Vec<usize> = (0..200).map(|i| i * 7 + k).collect();
                    assert_eq!(got, want, "submitter {k}");
                });
            }
        });
    }

    #[test]
    fn taskptr_send_call_collect_across_threads() {
        // The Miri slice (scripts/analyze.sh runs `cargo miri test ..
        // taskptr`): a *local* pool — its workers shut down and joined
        // at the end, since Miri rejects leaked threads — exercises the
        // full TaskPtr protocol: lifetime-erase the closure, send it to
        // workers, call through the erased fn pointer from several
        // threads, collect results by index, retire the wave.
        let (pool, handles) = pool::WorkerPool::with_workers(2);
        for n in [1usize, 2, 7] {
            let slots: Vec<Mutex<Option<usize>>> = (0..n).map(|_| Mutex::new(None)).collect();
            let task = |i: usize| {
                *slots[i].lock().expect("pool result slot poisoned") = Some(i * 3 + 1);
            };
            pool.run_wave(n, &task);
            let got: Vec<usize> = slots
                .into_iter()
                .map(|s| s.into_inner().unwrap().expect("missed index"))
                .collect();
            assert_eq!(got, (0..n).map(|i| i * 3 + 1).collect::<Vec<_>>(), "n={n}");
        }
        pool.shutdown();
        for h in handles {
            h.join().expect("pool worker panicked");
        }
    }

    #[test]
    fn taskptr_panic_payload_reraises_on_submitter() {
        // Same Miri slice, unhappy path: a panicking task is caught in
        // the wave, the wave still completes and retires (no dangling
        // TaskPtr stays queued), and the payload re-raises on the
        // submitter — after which the pool shuts down cleanly.
        let (pool, handles) = pool::WorkerPool::with_workers(1);
        let slots: Vec<Mutex<Option<usize>>> = (0..4).map(|_| Mutex::new(None)).collect();
        let task = |i: usize| {
            assert!(i != 2, "injected taskptr failure");
            *slots[i].lock().expect("pool result slot poisoned") = Some(i);
        };
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run_wave(4, &task)));
        assert!(outcome.is_err(), "the task panic must re-raise on the submitter");
        pool.shutdown();
        for h in handles {
            h.join().expect("a pool worker died: task panics must never unwind a worker");
        }
    }

    #[test]
    fn env_resolution_warns_and_falls_back_on_bad_values() {
        // Both env-var error paths, exercised through the pure
        // resolution core (no set_var: mutating the process environment
        // would race every other test thread reading it).
        // A bogus engine name falls back to serial...
        assert_eq!(Engine::resolve(Some("warp-drive"), Some("many")), Engine::Serial);
        // ...and an unparseable thread count falls back to 0 (all
        // cores), not garbage — for every engine kind.
        assert_eq!(Engine::resolve(Some("threaded"), Some("many")), Engine::threaded(0));
        assert_eq!(Engine::resolve(Some("spawning"), Some("lots")), Engine::spawning(0));
        // The healthy paths resolve exactly.
        assert_eq!(Engine::resolve(None, None), Engine::Serial);
        assert_eq!(Engine::resolve(Some("serial"), Some("3")), Engine::Serial);
        assert_eq!(Engine::resolve(Some("threaded"), Some("3")), Engine::threaded(3));
        assert_eq!(Engine::resolve(Some("pooled"), Some("3")), Engine::threaded(3));
        assert_eq!(Engine::resolve(Some("spawning"), Some("3")), Engine::spawning(3));
    }
}
