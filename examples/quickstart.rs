//! Quickstart: stand up an `SpmvService`, register a matrix once, and
//! serve requests against the handle — the load-once/serve-many shape
//! the whole library is organized around.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sparsep::coordinator::{KernelSpec, Request, ServiceBuilder};
use sparsep::matrix::generate;
use sparsep::pim::PimSystem;

fn main() -> sparsep::util::Result<()> {
    // 1. A sparse matrix. Generators mirror the paper's two matrix
    //    classes; @file.mtx loading is available via matrix::mtx.
    let m = generate::scale_free::<f32>(8192, 8192, 10, 0.6, 42);
    println!(
        "matrix: {}x{}, {} nnz (scale-free class)",
        m.nrows(),
        m.ncols(),
        m.nnz()
    );

    // 2. A service over a PIM system: 256 DPUs, 16 tasklets each (UPMEM
    //    defaults). The threaded engine runs per-DPU kernel simulations
    //    on host threads; the request queue pipelines the load / kernel
    //    / retrieve+merge stages across requests. Neither changes
    //    results — responses are bit-identical to synchronous serial
    //    execution.
    let svc = ServiceBuilder::new()
        .threads(0) // threaded engine, all cores
        .build::<f32>(PimSystem::with_dpus(256))?;

    // 3. Load once: partitioning, per-DPU format conversion and transfer
    //    pricing happen here — never again, however many requests
    //    follow. The handle is Copy; requests against it are hash-free.
    let handle = svc.load(&m, &KernelSpec::coo_nnz_rgrn())?;

    // 4. One SpMV request: exact result + modeled breakdown.
    let x = vec![1.0f32; m.ncols()];
    let run = svc.spmv(&handle, &x)?;
    assert_eq!(run.y, m.spmv(&x), "simulator output is exact");
    let b = run.breakdown;
    println!("verified: output matches host oracle");
    println!(
        "breakdown: load {:.3} ms | kernel {:.3} ms | retrieve {:.3} ms ({} dominated)",
        b.load_s * 1e3,
        b.kernel_s * 1e3,
        b.retrieve_s * 1e3,
        b.dominant()
    );
    println!(
        "kernel {:.2} GFLOP/s | e2e {:.2} GFLOP/s | imbalance {:.2}x | energy {:.2e} J",
        run.kernel_gflops(),
        run.e2e_gflops(),
        run.stats.dpu_imbalance,
        run.energy.total_j()
    );

    // 5. Typed requests + tickets: submit several kinds of work at
    //    once, claim the responses in any order. While the kernel stage
    //    simulates one request's block, the prep stage is already
    //    staging the next and the merge stage is finishing the previous.
    //    Payloads are shared `Arc<[T]>` slices (`Vec<T>` converts in):
    //    submitting clones references, never vector data.
    let t_batch = svc.submit(
        handle,
        Request::batch(
            (0..8)
                .map(|s| (0..m.ncols()).map(|i| ((i + s) % 5) as f32 - 2.0).collect())
                .collect::<Vec<Vec<f32>>>(),
        ),
    )?;
    let t_iter = svc.submit(handle, Request::iterate(x.clone(), 20))?;
    let t_one = svc.submit(handle, Request::spmv(x.clone()))?;

    // Out-of-order waits: responses park until claimed.
    let one = svc.wait(t_one)?.into_spmv()?;
    assert_eq!(one.y, run.y, "same request, same answer");
    let it = svc.wait(t_iter)?.into_iterations()?;
    println!(
        "20 iterations on one handle: {:.3} ms total ({:.3} ms/iter), placement paid once ({:.3} ms)",
        it.total.total_s() * 1e3,
        it.per_iter_s() * 1e3,
        it.last.stats.matrix_load_s * 1e3
    );
    let batch = svc.wait(t_batch)?.into_batch()?;
    println!(
        "batched serving: {} vectors in one request, {:.3} ms modeled total",
        batch.len(),
        batch.total().total_s() * 1e3
    );

    // 6. The service's plan cache is content-keyed: loading an equal
    //    matrix again (even a clone) is a hit, not a re-plan.
    let again = svc.load(&m.clone(), &KernelSpec::coo_nnz_rgrn())?;
    let st = svc.stats();
    println!(
        "service: {} requests served, cache {} hit / {} miss / {} build ({} handle(s))",
        st.completed, st.cache_hits, st.cache_misses, st.plan_builds, st.loaded_handles
    );
    assert_eq!(st.plan_builds, 1, "the clone re-used the resident plan");
    svc.unload(again);

    // 7. The same matrix through every kernel family, one line each.
    //    (A fresh handle per spec; each load plans once.)
    println!("\nall-25 sweep (total end-to-end ms):");
    for spec in KernelSpec::all25(8) {
        let h = svc.load(&m, &spec)?;
        let r = svc.spmv(&h, &x)?;
        println!("  {:<14} {:>9.3} ms", spec.name, r.breakdown.total_s() * 1e3);
        svc.unload(h);
    }
    Ok(())
}
