//! Autotuner benchmark/driver (`sparsep tune`).
//!
//! Runs the [`crate::coordinator::tuner`] search over the generated
//! suite, persists the winners as a loadable calibration table, and
//! writes `BENCH_tune.json` reporting calibrated-vs-heuristic speedup
//! per matrix class. Because the heuristic configuration is measured as
//! candidate zero of the same sweep, every row's speedup is ≥ 1.0 by
//! construction — this harness additionally *enforces* it (within
//! `tolerance`, guarding against pathological measurement environments)
//! so `scripts/ci.sh` can gate on the exit status alone.

use crate::coordinator::calibration::CalibrationTable;
use crate::coordinator::tuner::{tune, TuneOpts};
use crate::coordinator::Engine;
use crate::util::json::{num, s, Json};
use crate::util::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Knobs for [`run`] (CLI flags of `sparsep tune`). Zero-valued numeric
/// fields mean "use the mode's default" ([`TuneOpts::quick`] /
/// [`TuneOpts::full`]).
#[derive(Clone, Debug)]
pub struct TuneBenchOpts {
    /// `true` = mini-suite smoke search (seconds; the CI gate),
    /// `false` = full paper-scale search (minutes; run offline).
    pub quick: bool,
    /// Simulated DPUs per rank group (0 = mode default).
    pub n_dpus: usize,
    /// Tasklets per DPU (0 = mode default).
    pub tasklets: usize,
    /// Host threads for wall-clock measurement (0 = serial engine,
    /// the most reproducible choice).
    pub threads: usize,
    /// Timed repetitions per candidate (0 = mode default).
    pub samples: usize,
    /// Matrix-generator seed (0 = mode default).
    pub seed: u64,
    /// Where the calibration table lands (`run/serve --calibration`
    /// loads this file).
    pub table_out: String,
    /// Where the JSON report lands.
    pub out: String,
    /// Largest tolerated shortfall of `min(speedup)` below 1.0 before
    /// the run fails. Speedups are ≥ 1.0 by construction; the slack
    /// only absorbs measurement pathologies.
    pub tolerance: f64,
}

impl Default for TuneBenchOpts {
    fn default() -> TuneBenchOpts {
        TuneBenchOpts {
            quick: false,
            n_dpus: 0,
            tasklets: 0,
            threads: 0,
            samples: 0,
            seed: 0,
            table_out: "calibration.json".to_string(),
            out: "BENCH_tune.json".to_string(),
            tolerance: 0.02,
        }
    }
}

/// Run the search, save the table, write and gate the report.
pub fn run(opts: &TuneBenchOpts) -> Result<()> {
    crate::ensure!(opts.tolerance >= 0.0, "tune needs --tolerance >= 0");
    let mut topts = if opts.quick { TuneOpts::quick() } else { TuneOpts::full() };
    if opts.n_dpus > 0 {
        topts.n_dpus = opts.n_dpus;
    }
    if opts.tasklets > 0 {
        topts.tasklets = opts.tasklets;
    }
    if opts.samples > 0 {
        topts.samples = opts.samples;
    }
    if opts.seed > 0 {
        topts.seed = opts.seed;
    }
    if opts.threads > 0 {
        topts.engine = Engine::threaded(opts.threads);
    }
    println!(
        "tune: {} search, {} DPUs x {} tasklets, batches {:?}, blocks {:?}, shards {:?} x cols {:?} x replicas {:?}, top-{} kernels, {} samples",
        if topts.quick { "quick" } else { "full" },
        topts.n_dpus,
        topts.tasklets,
        topts.batches,
        topts.block_grid,
        topts.shard_grid,
        topts.col_grid,
        topts.replica_grid,
        topts.top_kernels,
        topts.samples
    );

    let report = tune(&topts)?;
    report.table.save(Path::new(&opts.table_out))?;

    let mut table = super::Table::new(&[
        "matrix", "class", "batch", "heuristic", "h_wall_ms", "winner", "block", "shards",
        "cols", "reps", "wall_ms", "speedup",
    ]);
    let mut rows_json = Vec::with_capacity(report.rows.len());
    // Per-class fold: min and geometric mean of the speedups.
    let mut per_class: BTreeMap<String, (f64, f64, usize)> = BTreeMap::new();
    for r in &report.rows {
        table.row(&[
            r.matrix.clone(),
            r.class.clone(),
            r.batch.to_string(),
            r.heuristic_kernel.clone(),
            format!("{:.3}", r.heuristic_wall_s * 1e3),
            r.kernel.clone(),
            r.block.to_string(),
            r.shards.to_string(),
            r.grid_cols.to_string(),
            r.replicas.to_string(),
            format!("{:.3}", r.wall_s * 1e3),
            format!("{:.2}x", r.speedup),
        ]);
        rows_json.push(crate::util::json::obj(vec![
            ("matrix", s(&r.matrix)),
            ("class", s(&r.class)),
            ("batch", num(r.batch as f64)),
            ("heuristic_kernel", s(&r.heuristic_kernel)),
            ("heuristic_block", num(r.heuristic_block as f64)),
            ("heuristic_wall_s", num(r.heuristic_wall_s)),
            ("kernel", s(&r.kernel)),
            ("block", num(r.block as f64)),
            ("shards", num(r.shards as f64)),
            ("grid_cols", num(r.grid_cols as f64)),
            ("replicas", num(r.replicas as f64)),
            ("wall_s", num(r.wall_s)),
            ("speedup", num(r.speedup)),
        ]));
        let c = per_class.entry(r.class.clone()).or_insert((f64::INFINITY, 0.0, 0));
        c.0 = c.0.min(r.speedup);
        c.1 += r.speedup.ln();
        c.2 += 1;
    }
    table.print();

    let mut fields: BTreeMap<String, Json> = BTreeMap::new();
    fields.insert("bench".into(), s("tune"));
    fields.insert("mode".into(), s(if topts.quick { "quick" } else { "full" }));
    fields.insert("dpus".into(), num(topts.n_dpus as f64));
    fields.insert("tasklets".into(), num(topts.tasklets as f64));
    fields.insert("samples".into(), num(topts.samples as f64));
    fields.insert("seed".into(), num(topts.seed as f64));
    fields.insert("entries".into(), num(report.table.len() as f64));
    fields.insert("calibration_table".into(), s(&opts.table_out));
    fields.insert("rows".into(), Json::Arr(rows_json));
    for (class, (min, lnsum, n)) in &per_class {
        let geo = (lnsum / *n as f64).exp();
        println!("  class {class:<11} min {min:>5.2}x  geomean {geo:>5.2}x over {n} cells");
        fields.insert(format!("class_{class}_min_speedup"), num(*min));
        fields.insert(format!("class_{class}_geomean_speedup"), num(geo));
    }
    let min_speedup = report.min_speedup();
    fields.insert("min_speedup".into(), num(min_speedup));
    std::fs::write(&opts.out, Json::Obj(fields).to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {} and {}", opts.out, opts.table_out);

    // The CI gate: calibrated selection must never lose to the
    // heuristic baseline beyond the tolerance. By construction the
    // minimum is ≥ 1.0; tripping this means the harness itself broke.
    crate::ensure!(
        min_speedup >= 1.0 - opts.tolerance,
        "calibrated selection regressed vs the heuristic: min speedup {min_speedup:.4} < {:.4}",
        1.0 - opts.tolerance
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_bench_smoke_writes_report_and_loadable_table() {
        let dir = std::env::temp_dir().join("sparsep_bench_tune_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_tune_test.json");
        let table_out = dir.join("calibration_test.json");
        let opts = TuneBenchOpts {
            quick: true,
            n_dpus: 16,
            tasklets: 8,
            samples: 1,
            table_out: table_out.to_str().unwrap().to_string(),
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();

        let txt = std::fs::read_to_string(&out).unwrap();
        let j = Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("tune"));
        assert_eq!(j.get("mode").as_str(), Some("quick"));
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 4, "one row per mini-suite matrix");
        for r in rows {
            assert!(r.get("speedup").as_f64().unwrap() >= 1.0);
            assert!(r.get("wall_s").as_f64().unwrap() > 0.0);
        }
        assert!(j.get("min_speedup").as_f64().unwrap() >= 1.0);
        assert!(j.get("class_regular_min_speedup").as_f64().unwrap() >= 1.0);
        assert!(j.get("class_scale-free_min_speedup").as_f64().unwrap() >= 1.0);

        // The emitted table is loadable (checksum verifies) and usable.
        let table = CalibrationTable::load(&table_out).unwrap();
        assert_eq!(table.len(), 4);
        std::fs::remove_file(&out).ok();
        std::fs::remove_file(&table_out).ok();
    }
}
