//! Execution metrics: the paper's end-to-end breakdown.
//!
//! The paper's scaling figures decompose SpMV time into *load* (input
//! vector transfer to PIM memory), *kernel* (DPU execution, max across
//! DPUs), *retrieve* (gathering outputs / partial results back over the
//! bus) and *merge* (host-side reduction of 2D partial results). The
//! one-time matrix placement is reported separately, matching the
//! paper's methodology (iterative solvers reuse the matrix across
//! thousands of SpMV calls).
//!
//! Every [`super::SpmvService`] response carries exactly these metric
//! types — a [`RunResult`] per [`super::Request::Spmv`], a
//! [`BatchResult`] per [`super::Request::Batch`], an
//! [`IterationsResult`] per [`super::Request::Iterate`] — and
//! [`ServiceStats`] summarizes the service-level counters (requests,
//! plan-cache traffic, resident plans).

use crate::pim::Energy;

/// Per-iteration time breakdown, seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    /// Input-vector transfer host -> PIM (broadcast for 1D, scatter of
    /// slices for 2D).
    pub load_s: f64,
    /// Kernel execution: slowest DPU.
    pub kernel_s: f64,
    /// Output (or partial-output) gather PIM -> host.
    pub retrieve_s: f64,
    /// Host-side merge of 2D partial results (0 for 1D).
    pub merge_s: f64,
}

impl Breakdown {
    pub fn total_s(&self) -> f64 {
        self.load_s + self.kernel_s + self.retrieve_s + self.merge_s
    }

    /// Add another iteration's breakdown into this one (used by the
    /// plan-once/execute-many accumulators).
    pub fn accumulate(&mut self, other: &Breakdown) {
        self.load_s += other.load_s;
        self.kernel_s += other.kernel_s;
        self.retrieve_s += other.retrieve_s;
        self.merge_s += other.merge_s;
    }

    /// Fraction of total spent in the kernel (the paper's "how much of
    /// the time is actual SpMV" lens).
    pub fn kernel_fraction(&self) -> f64 {
        let t = self.total_s();
        if t == 0.0 {
            0.0
        } else {
            self.kernel_s / t
        }
    }

    /// Dominant phase name.
    pub fn dominant(&self) -> &'static str {
        let phases = [
            (self.load_s, "load"),
            (self.kernel_s, "kernel"),
            (self.retrieve_s, "retrieve"),
            (self.merge_s, "merge"),
        ];
        phases
            .iter()
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .map(|&(_, n)| n)
            .unwrap()
    }
}

/// Structural statistics of one run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    /// Across-DPU compute imbalance (max/ideal, 1.0 = perfect).
    pub dpu_imbalance: f64,
    /// Slowest DPU's kernel cycles.
    pub kernel_cycles: u64,
    /// Bus bytes moved including padding, this iteration.
    pub bus_bytes_moved: u64,
    /// Bus bytes of useful payload, this iteration.
    pub bus_bytes_payload: u64,
    /// One-time matrix placement cost, seconds (not in the breakdown).
    pub matrix_load_s: f64,
    /// Number of DPUs used.
    pub n_dpus: usize,
    /// Non-zeros of the input matrix.
    pub nnz: usize,
}

impl RunStats {
    /// Padding overhead of this iteration's transfers (1.0 = none).
    pub fn padding_overhead(&self) -> f64 {
        if self.bus_bytes_payload == 0 {
            1.0
        } else {
            self.bus_bytes_moved as f64 / self.bus_bytes_payload as f64
        }
    }
}

/// Full result of one coordinated SpMV execution.
#[derive(Clone, Debug)]
pub struct RunResult<T> {
    /// The output vector (exact).
    pub y: Vec<T>,
    pub breakdown: Breakdown,
    pub stats: RunStats,
    pub energy: Energy,
}

impl<T> RunResult<T> {
    /// Kernel-only GFLOP/s (2 flops per non-zero).
    pub fn kernel_gflops(&self) -> f64 {
        if self.breakdown.kernel_s == 0.0 {
            0.0
        } else {
            2.0 * self.stats.nnz as f64 / self.breakdown.kernel_s / 1e9
        }
    }

    /// End-to-end GFLOP/s including transfers and merge.
    pub fn e2e_gflops(&self) -> f64 {
        let t = self.breakdown.total_s();
        if t == 0.0 {
            0.0
        } else {
            2.0 * self.stats.nnz as f64 / t / 1e9
        }
    }
}

/// Result of one batched execution
/// ([`super::SpmvExecutor::execute_batch`]): one full [`RunResult`] per
/// input vector, in input order.
///
/// Every run is bit-identical to what a single-vector
/// [`super::SpmvExecutor::execute`] of the same plan would have
/// produced — the model prices each vector as an independent SpMV;
/// batching amortizes the host-side simulation wall-clock (and, on a
/// real system, per-launch overheads), not the modeled per-vector cost.
#[derive(Clone, Debug)]
pub struct BatchResult<T> {
    /// Per-vector results, in input order.
    pub runs: Vec<RunResult<T>>,
}

impl<T> BatchResult<T> {
    /// Number of vectors in the batch.
    pub fn len(&self) -> usize {
        self.runs.len()
    }

    /// True for an empty batch.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// The output vectors, borrowed, in input order.
    pub fn ys(&self) -> Vec<&[T]> {
        self.runs.iter().map(|r| r.y.as_slice()).collect()
    }

    /// The output vectors, owned, in input order (drops the metrics).
    pub fn into_ys(self) -> Vec<Vec<T>> {
        self.runs.into_iter().map(|r| r.y).collect()
    }

    /// Modeled per-iteration cost summed across the batch.
    pub fn total(&self) -> Breakdown {
        let mut b = Breakdown::default();
        for r in &self.runs {
            b.accumulate(&r.breakdown);
        }
        b
    }

    /// Modeled energy summed across the batch.
    pub fn energy(&self) -> Energy {
        self.runs.iter().fold(Energy::default(), |acc, r| acc.add(r.energy))
    }
}

/// Result of an iterated batched SpMV (`y_b <- A*y_b` for every vector
/// in the batch, `iters` times) over one plan. Produced by
/// [`super::SpmvExecutor::run_iterations_batch`].
#[derive(Clone, Debug)]
pub struct BatchIterationsResult<T> {
    /// The final iteration (its `runs[b].y` are the overall outputs).
    pub last: BatchResult<T>,
    /// Per-iteration breakdowns summed over all iterations and vectors.
    pub total: Breakdown,
    /// Modeled energy summed over all iterations and vectors.
    pub energy: Energy,
    /// Number of iterations applied to every vector.
    pub iters: usize,
}

impl<T> BatchIterationsResult<T> {
    /// Number of vectors in the batch.
    pub fn batch(&self) -> usize {
        self.last.len()
    }

    /// Mean modeled time per (iteration, vector) SpMV, seconds.
    pub fn per_spmv_s(&self) -> f64 {
        self.total.total_s() / (self.iters.max(1) * self.last.len().max(1)) as f64
    }
}

/// Service-level counters reported by [`super::SpmvService::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests accepted since the service was built: tickets issued by
    /// `submit` plus synchronous fast-path calls.
    pub submitted: u64,
    /// Requests finished: responses published by the request engine
    /// (claimed or not) plus synchronous fast-path calls.
    pub completed: u64,
    /// Plan-cache lookups served from cache (includes single-flight
    /// waiters that shared a concurrent build).
    pub cache_hits: u64,
    /// Plan-cache lookups that had to build.
    pub cache_misses: u64,
    /// Successful plan builds.
    pub plan_builds: u64,
    /// Plans currently resident in the cache.
    pub resident_plans: usize,
    /// Matrix handles currently registered with the service.
    pub loaded_handles: usize,
}

impl ServiceStats {
    /// Requests submitted but not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.submitted.saturating_sub(self.completed)
    }
}

/// Log-bucketed latency histogram: bucket `i` counts samples in
/// `[2^(i-1), 2^i)` microseconds (bucket 0 holds sub-microsecond
/// samples). Recording is O(1) with no allocation; quantiles report a
/// bucket's inclusive upper bound, so snapshots are exact integers —
/// deterministic and `Eq`-comparable, never interpolated floats.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; Self::BUCKETS],
    count: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; Self::BUCKETS], count: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    /// Bucket count: 48 power-of-two buckets span sub-microsecond to
    /// ~4.5 years, so no realistic latency saturates the top bucket.
    pub const BUCKETS: usize = 48;

    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    fn bucket_of(us: u64) -> usize {
        // Number of significant bits: 0us -> bucket 0, 1us -> 1,
        // [2,4)us -> 2, ... clamped into the top bucket.
        ((u64::BITS - us.leading_zeros()) as usize).min(Self::BUCKETS - 1)
    }

    /// Record one latency sample, in microseconds.
    pub fn record(&mut self, us: u64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The quantile `q` in `[0, 1]` as the inclusive upper bound (in
    /// microseconds) of the bucket holding the rank-`ceil(q*count)`
    /// sample; 0 when empty. The true sample is never larger.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                // Bucket i covers [2^(i-1), 2^i): upper bound 2^i - 1,
                // except bucket 0 which only holds 0us samples. The
                // max bucket is additionally capped by the observed max.
                let hi = if i == 0 { 0 } else { (1u64 << i.min(63)) - 1 };
                return hi.min(self.max_us);
            }
        }
        self.max_us
    }

    /// Freeze p50/p99/p999 (plus count and max) into an `Eq`-comparable
    /// integer snapshot.
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.count,
            p50_us: self.quantile_us(0.50),
            p99_us: self.quantile_us(0.99),
            p999_us: self.quantile_us(0.999),
            max_us: self.max_us,
        }
    }
}

/// Integer-microsecond percentile snapshot of a [`LatencyHistogram`]
/// (all fields are exact integers so the containing [`TenantStats`]
/// stays `Eq`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencySnapshot {
    /// Latency samples recorded (completed scheduled requests).
    pub count: u64,
    /// Median: inclusive upper bound of the p50 bucket, microseconds.
    pub p50_us: u64,
    /// 99th percentile bucket upper bound, microseconds.
    pub p99_us: u64,
    /// 99.9th percentile bucket upper bound, microseconds.
    pub p999_us: u64,
    /// Largest single sample, microseconds.
    pub max_us: u64,
}

/// Per-tenant scheduling counters reported by
/// [`super::ShardedService::stats`] (one per registered tenant, in
/// registration order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantStats {
    /// Tenant name (interned: shares the scheduler's `Arc<str>`, so
    /// snapshotting stats allocates no strings).
    pub name: crate::util::sync::Arc<str>,
    /// Weighted-round-robin share (dispatches per scheduling cycle).
    pub weight: usize,
    /// In-flight quota (`usize::MAX` = unlimited).
    pub max_in_flight: usize,
    /// Requests accepted into the tenant's queue.
    pub enqueued: u64,
    /// Requests dispatched to the shard backends.
    pub dispatched: u64,
    /// Requests completed (response published).
    pub completed: u64,
    /// Requests shed by admission control with [`super::Response::Overloaded`]
    /// (never queued; not counted in `enqueued`).
    pub shed: u64,
    /// Requests currently dispatched but not completed.
    pub in_flight: usize,
    /// Requests still queued behind the scheduler.
    pub queued: usize,
    /// Submit-to-publish latency percentiles over this tenant's
    /// completed scheduled requests (log-bucketed; integer us).
    pub latency: LatencySnapshot,
}

/// Facade-level counters reported by [`super::ShardedService::stats`]:
/// scheduled-request totals plus the shared plan-cache traffic and the
/// per-tenant scheduling counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardedStats {
    /// Shard count: distinct tiles (`grid_rows * grid_cols`), not
    /// replica slots — replicas multiply capacity, not ownership.
    pub shards: usize,
    /// Configured row bands of the tile grid.
    pub grid_rows: usize,
    /// Configured column stripes per band (1 = row-only sharding).
    pub grid_cols: usize,
    /// Configured replicas per tile (1 = unreplicated).
    pub replicas: usize,
    /// Requests accepted by the facade: tickets issued by `submit` /
    /// `submit_for` plus synchronous fast-path calls.
    pub submitted: u64,
    /// Requests finished: responses published (claimed or not) plus
    /// synchronous fast-path calls.
    pub completed: u64,
    /// Sharded handles currently registered with the facade.
    pub loaded_handles: usize,
    /// Shared plan-cache lookups served from cache.
    pub cache_hits: u64,
    /// Shared plan-cache lookups that had to build.
    pub cache_misses: u64,
    /// Successful plan builds in the shared cache.
    pub plan_builds: u64,
    /// Plans resident in the shared cache.
    pub resident_plans: usize,
    /// Backend shard services respawned by supervision after a kill
    /// (each respawn re-plans from the shared cache: hits, not builds).
    pub respawns: u64,
    /// Per-tenant scheduling counters, in registration order.
    pub tenants: Vec<TenantStats>,
}

impl ShardedStats {
    /// Requests submitted but not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.submitted.saturating_sub(self.completed)
    }
}

/// Result of an iterated SpMV (`y <- A*y`, `iters` times) over one plan:
/// the final iteration's full [`RunResult`] plus cost totals across all
/// iterations. Produced by [`super::SpmvExecutor::run_iterations`].
#[derive(Clone, Debug)]
pub struct IterationsResult<T> {
    /// The final iteration (its `y` is the overall output).
    pub last: RunResult<T>,
    /// Per-iteration breakdowns summed over all iterations.
    pub total: Breakdown,
    /// Modeled energy summed over all iterations.
    pub energy: Energy,
    pub iters: usize,
}

impl<T> IterationsResult<T> {
    /// Final output vector.
    pub fn y(&self) -> &[T] {
        &self.last.y
    }

    /// Mean per-iteration time, seconds.
    pub fn per_iter_s(&self) -> f64 {
        self.total.total_s() / self.iters.max(1) as f64
    }

    /// End-to-end seconds including the one-time matrix placement.
    pub fn total_with_placement_s(&self) -> f64 {
        self.last.stats.matrix_load_s + self.total.total_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let b = Breakdown { load_s: 1.0, kernel_s: 2.0, retrieve_s: 0.5, merge_s: 0.5 };
        assert_eq!(b.total_s(), 4.0);
        assert_eq!(b.kernel_fraction(), 0.5);
        assert_eq!(b.dominant(), "kernel");
    }

    #[test]
    fn accumulate_sums_fields() {
        let mut acc = Breakdown::default();
        let b = Breakdown { load_s: 1.0, kernel_s: 2.0, retrieve_s: 0.5, merge_s: 0.25 };
        acc.accumulate(&b);
        acc.accumulate(&b);
        assert_eq!(acc.total_s(), 7.5);
        assert_eq!(acc.kernel_s, 4.0);
    }

    #[test]
    fn iterations_result_helpers() {
        let last = RunResult {
            y: vec![1.0f64],
            breakdown: Breakdown { kernel_s: 1.0, ..Default::default() },
            stats: RunStats { matrix_load_s: 0.5, ..Default::default() },
            energy: Energy::default(),
        };
        let it = IterationsResult {
            last,
            total: Breakdown { kernel_s: 10.0, ..Default::default() },
            energy: Energy::default(),
            iters: 5,
        };
        assert_eq!(it.y(), &[1.0]);
        assert_eq!(it.per_iter_s(), 2.0);
        assert_eq!(it.total_with_placement_s(), 10.5);
    }

    #[test]
    fn dominant_picks_load() {
        let b = Breakdown { load_s: 5.0, kernel_s: 2.0, ..Default::default() };
        assert_eq!(b.dominant(), "load");
    }

    #[test]
    fn padding_overhead() {
        let s = RunStats { bus_bytes_moved: 200, bus_bytes_payload: 100, ..Default::default() };
        assert_eq!(s.padding_overhead(), 2.0);
        assert_eq!(RunStats::default().padding_overhead(), 1.0);
    }

    #[test]
    fn batch_result_helpers() {
        let mk = |v: f64| RunResult {
            y: vec![v],
            breakdown: Breakdown { kernel_s: 1.0, ..Default::default() },
            stats: RunStats::default(),
            energy: Energy::default(),
        };
        let b = BatchResult { runs: vec![mk(1.0), mk(2.0)] };
        assert_eq!(b.len(), 2);
        assert!(!b.is_empty());
        assert_eq!(b.total().kernel_s, 2.0);
        assert_eq!(b.ys(), vec![&[1.0][..], &[2.0][..]]);
        let it = BatchIterationsResult {
            last: b.clone(),
            total: Breakdown { kernel_s: 12.0, ..Default::default() },
            energy: Energy::default(),
            iters: 3,
        };
        assert_eq!(it.batch(), 2);
        assert_eq!(it.per_spmv_s(), 2.0);
        assert_eq!(b.into_ys(), vec![vec![1.0], vec![2.0]]);
    }

    #[test]
    fn service_stats_in_flight() {
        let s = ServiceStats { submitted: 5, completed: 3, ..Default::default() };
        assert_eq!(s.in_flight(), 2);
        assert_eq!(ServiceStats::default().in_flight(), 0);
    }

    #[test]
    fn latency_histogram_buckets_and_quantiles() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.snapshot(), LatencySnapshot::default(), "empty = all zeros");
        // 100 samples of 100us: every quantile lands in the [64,128)
        // bucket, reported as its inclusive upper bound capped by max.
        for _ in 0..100 {
            h.record(100);
        }
        assert_eq!(h.count(), 100);
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max_us, 100);
        assert_eq!(s.p50_us, 100, "bucket bound 127 capped by observed max");
        assert_eq!(s.p99_us, 100);
        assert_eq!(s.p999_us, 100);
        // One slow outlier dominates the tail but not the median.
        h.record(1_000_000);
        let s = h.snapshot();
        assert_eq!(s.p50_us, 100);
        assert_eq!(s.max_us, 1_000_000);
        assert!(s.p999_us >= 1_000_000 || s.p999_us == 100);
    }

    #[test]
    fn latency_histogram_is_deterministic_and_eq() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for us in [0u64, 1, 2, 3, 900, 7_777, u64::MAX / 2] {
            a.record(us);
            b.record(us);
        }
        assert_eq!(a, b);
        assert_eq!(a.snapshot(), b.snapshot());
        // Zero-microsecond samples stay in bucket 0.
        let mut z = LatencyHistogram::new();
        z.record(0);
        assert_eq!(z.snapshot().p50_us, 0);
        assert_eq!(z.snapshot().max_us, 0);
    }

    #[test]
    fn gflops_accounting() {
        let r = RunResult {
            y: vec![0.0f32],
            breakdown: Breakdown { kernel_s: 1e-3, ..Default::default() },
            stats: RunStats { nnz: 1_000_000, ..Default::default() },
            energy: Energy::default(),
        };
        assert!((r.kernel_gflops() - 2.0).abs() < 1e-9);
    }
}
