//! PageRank power iteration on the PIM service (graph-analytics
//! workload — the scale-free matrices of the paper's suite are exactly
//! web/social graph adjacency structures).

use super::SolveStats;
use crate::coordinator::{KernelSpec, Request, ShardedService, SpmvService, TenantId};
use crate::matrix::CooMatrix;
use crate::util::Result;

/// PageRank outcome.
#[derive(Clone, Debug)]
pub struct PageRankResult {
    pub ranks: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub stats: SolveStats,
}

/// Column-stochastic transition matrix from an adjacency pattern:
/// `P[j,i] = 1/outdeg(i)` for each edge i->j (value sign/magnitude of
/// the input is ignored; the pattern is the graph).
pub fn transition_matrix(adj: &CooMatrix<f64>) -> CooMatrix<f64> {
    let n = adj.nrows().max(adj.ncols());
    let mut outdeg = vec![0usize; n];
    for (r, _c, _v) in adj.iter() {
        outdeg[r as usize] += 1;
    }
    let triples = adj
        .iter()
        .map(|(r, c, _v)| (c, r, 1.0 / outdeg[r as usize] as f64))
        .collect();
    CooMatrix::from_triples(n, n, triples)
}

/// Power iteration: `rank = d * P * rank + (1-d)/n`, until the L1 delta
/// falls below `tol`.
pub fn pagerank(
    svc: &SpmvService<f64>,
    spec: &KernelSpec,
    p: &CooMatrix<f64>,
    damping: f64,
    tol: f64,
    max_iters: usize,
) -> Result<PageRankResult> {
    crate::ensure!(p.nrows() == p.ncols(), "transition matrix must be square");
    let n = p.nrows();
    // Load once: the transition matrix is fixed across power iterations.
    let handle = svc.load(p, spec)?;
    let mut stats = SolveStats::default();
    let mut rank = vec![1.0 / n as f64; n];
    let teleport = (1.0 - damping) / n as f64;
    let mut converged = false;
    let mut iterations = 0;

    for _ in 0..max_iters {
        let run = svc.spmv(&handle, &rank)?;
        stats.absorb(&run);
        let mut next: Vec<f64> = run.y.iter().map(|v| damping * v + teleport).collect();
        // Redistribute dangling mass so the vector stays a distribution.
        let mass: f64 = next.iter().sum();
        let fix = (1.0 - mass) / n as f64;
        for v in next.iter_mut() {
            *v += fix;
        }
        let delta: f64 = next.iter().zip(&rank).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        iterations += 1;
        if delta < tol {
            converged = true;
            break;
        }
    }
    // Release the handle's plan pin: a long-lived service must not
    // accumulate one resident plan per solve call.
    svc.unload(handle);
    Ok(PageRankResult { ranks: rank, iterations, converged, stats })
}

/// Multi-seed personalized PageRank outcome: one ranking per seed.
#[derive(Clone, Debug)]
pub struct MultiPageRankResult {
    /// Per-seed rank distributions, in `seeds` order.
    pub ranks: Vec<Vec<f64>>,
    /// Power iterations until every seed converged (or `max_iters`).
    pub iterations: usize,
    /// True when every seed's L1 delta fell below `tol`.
    pub converged: bool,
    /// Accumulated PIM cost across all iterations and seeds.
    pub stats: SolveStats,
}

/// Multi-seed personalized PageRank on the PIM service — the
/// scenario-diversity demo for the batched serving path: N teleport
/// distributions (one per seed node) power-iterate against one resident
/// transition matrix, advancing in lockstep through batched requests
/// ([`crate::coordinator::Request::Batch`]) so every iteration is a
/// single pipelined wave instead of N.
///
/// Per seed `s`: `rank = d * P * rank + (1-d) * e_s`, with dangling and
/// rounding mass redistributed to the seed so each vector stays a
/// distribution. Iteration stops when the worst seed's L1 delta falls
/// below `tol`.
pub fn personalized_pagerank(
    svc: &SpmvService<f64>,
    spec: &KernelSpec,
    p: &CooMatrix<f64>,
    seeds: &[usize],
    damping: f64,
    tol: f64,
    max_iters: usize,
) -> Result<MultiPageRankResult> {
    crate::ensure!(p.nrows() == p.ncols(), "transition matrix must be square");
    crate::ensure!(!seeds.is_empty(), "personalized PageRank needs at least one seed");
    let n = p.nrows();
    for &s in seeds {
        crate::ensure!(s < n, "seed {s} out of range for {n} nodes");
    }
    // Load once: the transition matrix is shared by every seed and every
    // power iteration.
    let handle = svc.load(p, spec)?;
    let mut stats = SolveStats::default();
    let mut ranks: Vec<Vec<f64>> = seeds
        .iter()
        .map(|&s| {
            let mut e = vec![0.0; n];
            e[s] = 1.0;
            e
        })
        .collect();
    let mut converged = false;
    let mut iterations = 0;

    for _ in 0..max_iters {
        let batch = svc.spmv_batch(&handle, &ranks)?;
        iterations += 1;
        stats.iterations = iterations;
        for run in &batch.runs {
            stats.pim.accumulate(&run.breakdown);
            stats.energy_j += run.energy.total_j();
            stats.matrix_load_s = run.stats.matrix_load_s; // one-time
        }
        let mut max_delta = 0.0f64;
        for ((rank, run), &seed) in ranks.iter_mut().zip(&batch.runs).zip(seeds) {
            let (next, delta) = personalized_step(&run.y, rank, seed, damping);
            max_delta = max_delta.max(delta);
            *rank = next;
        }
        if max_delta < tol {
            converged = true;
            break;
        }
    }
    svc.unload(handle); // release the plan pin (see `pagerank`)
    Ok(MultiPageRankResult { ranks, iterations, converged, stats })
}

/// One step of the personalized power iteration for a single seed:
/// damp the SpMV output, teleport to the seed, and return the seed's
/// restart-corrected next distribution plus the L1 delta. Shared by the
/// single-service, multi-tenant and host-oracle paths so they iterate
/// the *same* math.
fn personalized_step(y: &[f64], rank: &[f64], seed: usize, damping: f64) -> (Vec<f64>, f64) {
    let mut next: Vec<f64> = y.iter().map(|v| damping * v).collect();
    next[seed] += 1.0 - damping;
    // Dangling nodes leak `damping * mass`; in the personalized walk
    // that mass restarts at the seed.
    let mass: f64 = next.iter().sum();
    next[seed] += 1.0 - mass;
    let delta: f64 = next.iter().zip(rank).map(|(a, b)| (a - b).abs()).sum();
    (next, delta)
}

/// Multi-tenant personalized PageRank on a [`ShardedService`] — the
/// serving-tier demo: every tenant brings its own seed set, loads its
/// own handle over the shared transition matrix (the shared plan cache
/// makes the per-shard plans build once), and power-iterates through
/// batched requests submitted on its own [`TenantId`] — so concurrent
/// tenants' waves are admitted by the weighted-round-robin scheduler,
/// not by submission luck. Each tenant stops when *its* worst seed
/// converges; all unconverged tenants' waves stay in flight together.
///
/// Returns one [`MultiPageRankResult`] per entry of `tenant_seeds`, in
/// input order, each bit-for-bit the same math as
/// [`personalized_pagerank`] runs on a plain service. Handles are
/// unloaded before returning (a long-lived facade must not accumulate
/// plan pins per call).
pub fn multi_tenant_personalized_pagerank(
    svc: &ShardedService<f64>,
    spec: &KernelSpec,
    p: &CooMatrix<f64>,
    tenant_seeds: &[(TenantId, Vec<usize>)],
    damping: f64,
    tol: f64,
    max_iters: usize,
) -> Result<Vec<MultiPageRankResult>> {
    crate::ensure!(p.nrows() == p.ncols(), "transition matrix must be square");
    crate::ensure!(!tenant_seeds.is_empty(), "need at least one tenant");
    let n = p.nrows();
    for (t, seeds) in tenant_seeds {
        crate::ensure!(!seeds.is_empty(), "tenant {} needs at least one seed", t.index());
        for &s in seeds {
            crate::ensure!(s < n, "seed {s} out of range for {n} nodes");
        }
    }

    struct TenantRun {
        tenant: TenantId,
        seeds: Vec<usize>,
        handle: crate::coordinator::ShardedHandle,
        ranks: Vec<Vec<f64>>,
        stats: SolveStats,
        iterations: usize,
        converged: bool,
    }
    let mut runs: Vec<TenantRun> = Vec::with_capacity(tenant_seeds.len());
    for (t, seeds) in tenant_seeds {
        let handle = match svc.load_for(*t, p, spec) {
            Ok(h) => h,
            Err(e) => {
                // Roll back earlier tenants' loads: no exit path may
                // leave plan pins behind on a long-lived facade.
                for r in &runs {
                    svc.unload(r.handle);
                }
                return Err(e);
            }
        };
        runs.push(TenantRun {
            tenant: *t,
            seeds: seeds.clone(),
            handle,
            ranks: seeds
                .iter()
                .map(|&s| {
                    let mut e = vec![0.0; n];
                    e[s] = 1.0;
                    e
                })
                .collect(),
            stats: SolveStats::default(),
            iterations: 0,
            converged: false,
        });
    }

    // The iteration loop as an inner closure so every exit path —
    // success or error — flows through the handle unload below: a
    // failing wave must not leave plan pins behind on a long-lived
    // facade.
    let mut iterate_all = || -> Result<()> {
        for _ in 0..max_iters {
            // One batched wave per unconverged tenant, all in flight at
            // once; the facade's scheduler interleaves them fairly. A
            // failing submit does not short-circuit: every ticket
            // already issued must still be claimed below, or its
            // response would park in the facade's completion store for
            // the service's lifetime.
            let mut tickets: Vec<(usize, crate::coordinator::ShardedTicket)> = Vec::new();
            let mut wave_err = None;
            for (i, r) in runs.iter().enumerate().filter(|(_, r)| !r.converged) {
                match svc.submit_for(r.tenant, r.handle, Request::batch(r.ranks.clone()))
                {
                    Ok(t) => tickets.push((i, t)),
                    Err(e) => {
                        wave_err = Some(e);
                        break;
                    }
                }
            }
            if tickets.is_empty() && wave_err.is_none() {
                break;
            }
            for (i, ticket) in tickets {
                // Claim every ticket even after an error (discarding
                // the response); the first error wins.
                let batch = match svc.wait(ticket).and_then(crate::coordinator::Response::into_batch) {
                    Ok(b) => b,
                    Err(e) => {
                        wave_err = wave_err.or(Some(e));
                        continue;
                    }
                };
                if wave_err.is_some() {
                    continue;
                }
                let run = &mut runs[i];
                run.iterations += 1;
                run.stats.iterations = run.iterations;
                for r in &batch.runs {
                    run.stats.pim.accumulate(&r.breakdown);
                    run.stats.energy_j += r.energy.total_j();
                    run.stats.matrix_load_s = r.stats.matrix_load_s; // one-time
                }
                let mut max_delta = 0.0f64;
                for ((rank, r), &seed) in run.ranks.iter_mut().zip(&batch.runs).zip(&run.seeds)
                {
                    let (next, delta) = personalized_step(&r.y, rank, seed, damping);
                    max_delta = max_delta.max(delta);
                    *rank = next;
                }
                if max_delta < tol {
                    run.converged = true;
                }
            }
            if let Some(e) = wave_err {
                return Err(e);
            }
        }
        Ok(())
    };
    let outcome = iterate_all();
    let results = runs
        .into_iter()
        .map(|r| {
            svc.unload(r.handle); // release this tenant's plan pins
            MultiPageRankResult {
                ranks: r.ranks,
                iterations: r.iterations,
                converged: r.converged,
                stats: r.stats,
            }
        })
        .collect();
    outcome?;
    Ok(results)
}

/// Host-only oracle for [`personalized_pagerank`] (single seed), used by
/// tests and verification.
pub fn personalized_pagerank_host(
    p: &CooMatrix<f64>,
    seed: usize,
    damping: f64,
    tol: f64,
    max_iters: usize,
) -> Vec<f64> {
    let mut rank = vec![0.0; p.nrows()];
    rank[seed] = 1.0;
    for _ in 0..max_iters {
        let y = p.spmv(&rank);
        let (next, delta) = personalized_step(&y, &rank, seed, damping);
        rank = next;
        if delta < tol {
            break;
        }
    }
    rank
}

/// Host-only oracle for tests.
pub fn pagerank_host(p: &CooMatrix<f64>, damping: f64, tol: f64, max_iters: usize) -> Vec<f64> {
    let n = p.nrows();
    let mut rank = vec![1.0 / n as f64; n];
    let teleport = (1.0 - damping) / n as f64;
    for _ in 0..max_iters {
        let y = p.spmv(&rank);
        let mut next: Vec<f64> = y.iter().map(|v| damping * v + teleport).collect();
        let mass: f64 = next.iter().sum();
        let fix = (1.0 - mass) / n as f64;
        for v in next.iter_mut() {
            *v += fix;
        }
        let delta: f64 = next.iter().zip(&rank).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        if delta < tol {
            break;
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceBuilder;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    fn service(n_dpus: usize) -> SpmvService<f64> {
        ServiceBuilder::new().build(PimSystem::with_dpus(n_dpus)).unwrap()
    }

    #[test]
    fn pagerank_matches_host_oracle_exactly() {
        let adj = generate::scale_free::<f64>(400, 400, 6, 0.6, 3);
        let p = transition_matrix(&adj);
        let svc = service(16);
        let res = pagerank(&svc, &KernelSpec::coo_nnz(), &p, 0.85, 1e-10, 100).unwrap();
        let oracle = pagerank_host(&p, 0.85, 1e-10, 100);
        // The PIM SpMV computes the same sums in a different association
        // order (per-DPU partials), so match to float round-off.
        for i in 0..400 {
            assert!(
                (res.ranks[i] - oracle[i]).abs() <= 1e-12 * oracle[i].abs().max(1e-12),
                "rank {i}: {} vs {}",
                res.ranks[i],
                oracle[i]
            );
        }
        assert!(res.converged);
    }

    #[test]
    fn ranks_form_a_distribution() {
        let adj = generate::uniform::<f64>(200, 200, 5, 9);
        let p = transition_matrix(&adj);
        let svc = service(8);
        let res = pagerank(&svc, &KernelSpec::coo_nnz_rgrn(), &p, 0.85, 1e-9, 200).unwrap();
        let sum: f64 = res.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "mass {sum}");
        assert!(res.ranks.iter().all(|&r| r >= 0.0));
    }

    #[test]
    fn personalized_multi_seed_matches_single_seed_host_oracle() {
        let adj = generate::scale_free::<f64>(300, 300, 6, 0.6, 7);
        let p = transition_matrix(&adj);
        let svc = service(8);
        let seeds = [0usize, 17, 123, 250];
        let res =
            personalized_pagerank(&svc, &KernelSpec::coo_nnz(), &p, &seeds, 0.85, 1e-10, 300)
                .unwrap();
        assert!(res.converged);
        assert_eq!(res.ranks.len(), seeds.len());
        for (ranks, &seed) in res.ranks.iter().zip(&seeds) {
            // The batched PIM walk may run extra iterations after this
            // seed converged (lockstep with the slowest seed) and sums
            // per-DPU partials in a different association order, so
            // match to a small multiple of the tolerance.
            let oracle = personalized_pagerank_host(&p, seed, 0.85, 1e-10, 300);
            for i in 0..300 {
                assert!(
                    (ranks[i] - oracle[i]).abs() <= 1e-8,
                    "seed {seed} rank {i}: {} vs {}",
                    ranks[i],
                    oracle[i]
                );
            }
            let mass: f64 = ranks.iter().sum();
            assert!((mass - 1.0).abs() < 1e-9, "seed {seed} mass {mass}");
        }
        assert!(res.stats.pim.total_s() > 0.0);
    }

    #[test]
    fn personalized_rank_concentrates_near_its_seed() {
        // Two disjoint 3-cycles: a walk personalized to one cycle never
        // leaves it (up to teleport), so its nodes out-rank the other's.
        let triples: Vec<(u32, u32, f64)> = vec![
            (0, 1, 1.0), (1, 2, 1.0), (2, 0, 1.0),
            (3, 4, 1.0), (4, 5, 1.0), (5, 3, 1.0),
        ];
        let adj = crate::matrix::CooMatrix::from_triples(6, 6, triples);
        let p = transition_matrix(&adj);
        let svc = service(2);
        let res =
            personalized_pagerank(&svc, &KernelSpec::coo_row(), &p, &[0, 3], 0.85, 1e-12, 500)
                .unwrap();
        for i in 0..3 {
            assert!(res.ranks[0][i] > res.ranks[0][i + 3], "seed-0 walk stays in cycle 0");
            assert!(res.ranks[1][i + 3] > res.ranks[1][i], "seed-3 walk stays in cycle 1");
        }
    }

    #[test]
    fn multi_tenant_personalized_matches_host_oracle() {
        use crate::coordinator::{ShardedServiceBuilder, TenantSpec};
        let adj = generate::scale_free::<f64>(250, 250, 6, 0.6, 13);
        let p = transition_matrix(&adj);
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(3)
            .tenants(vec![TenantSpec::new("research", 2), TenantSpec::new("ads", 1)])
            .build(PimSystem::with_dpus(8))
            .unwrap();
        let (tr, ta) = (svc.tenant("research").unwrap(), svc.tenant("ads").unwrap());
        let assignments = vec![(tr, vec![0usize, 41, 199]), (ta, vec![7usize, 120])];
        let results = multi_tenant_personalized_pagerank(
            &svc, &KernelSpec::coo_nnz(), &p, &assignments, 0.85, 1e-10, 300,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        for ((_, seeds), res) in assignments.iter().zip(&results) {
            assert!(res.converged);
            assert_eq!(res.ranks.len(), seeds.len());
            for (ranks, &seed) in res.ranks.iter().zip(seeds) {
                let oracle = personalized_pagerank_host(&p, seed, 0.85, 1e-10, 300);
                for i in 0..250 {
                    assert!(
                        (ranks[i] - oracle[i]).abs() <= 1e-8,
                        "seed {seed} rank {i}: {} vs {}",
                        ranks[i],
                        oracle[i]
                    );
                }
                let mass: f64 = ranks.iter().sum();
                assert!((mass - 1.0).abs() < 1e-9, "seed {seed} mass {mass}");
            }
            assert!(res.stats.pim.total_s() > 0.0);
        }
        // Handles were released on return (no plan-pin accumulation).
        assert_eq!(svc.stats().loaded_handles, 0, "handles must be released");
    }

    #[test]
    fn multi_tenant_personalized_validates_inputs() {
        use crate::coordinator::ShardedServiceBuilder;
        let adj = generate::uniform::<f64>(40, 40, 4, 3);
        let p = transition_matrix(&adj);
        let svc: ShardedService<f64> =
            ShardedServiceBuilder::new().shards(2).build(PimSystem::with_dpus(4)).unwrap();
        let t = svc.default_tenant();
        assert!(multi_tenant_personalized_pagerank(
            &svc, &KernelSpec::coo_row(), &p, &[], 0.85, 1e-9, 10
        )
        .is_err());
        assert!(multi_tenant_personalized_pagerank(
            &svc, &KernelSpec::coo_row(), &p, &[(t, vec![])], 0.85, 1e-9, 10
        )
        .is_err());
        assert!(multi_tenant_personalized_pagerank(
            &svc, &KernelSpec::coo_row(), &p, &[(t, vec![40])], 0.85, 1e-9, 10
        )
        .is_err());
        // A valid single-tenant run agrees with the plain-service path.
        let plain = super::personalized_pagerank(
            &service(4), &KernelSpec::coo_row(), &p, &[3, 9], 0.85, 1e-10, 200,
        )
        .unwrap();
        let sharded = multi_tenant_personalized_pagerank(
            &svc, &KernelSpec::coo_row(), &p, &[(t, vec![3, 9])], 0.85, 1e-10, 200,
        )
        .unwrap();
        // Same update rule; the sharded SpMV associates row sums
        // differently (per-shard partials), so allow float round-off
        // plus up to one extra iteration near the tolerance crossing.
        assert!(sharded[0].converged && plain.converged);
        for (a, b) in sharded[0].ranks.iter().zip(&plain.ranks) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-8, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn personalized_rejects_bad_seeds() {
        let adj = generate::uniform::<f64>(50, 50, 4, 3);
        let p = transition_matrix(&adj);
        let svc = service(4);
        assert!(personalized_pagerank(&svc, &KernelSpec::coo_row(), &p, &[], 0.85, 1e-9, 10)
            .is_err());
        assert!(personalized_pagerank(&svc, &KernelSpec::coo_row(), &p, &[50], 0.85, 1e-9, 10)
            .is_err());
    }

    #[test]
    fn hub_nodes_rank_higher() {
        // Star graph: everything points at node 0.
        let triples: Vec<(u32, u32, f64)> = (1..100u32).map(|i| (i, 0, 1.0)).collect();
        let adj = crate::matrix::CooMatrix::from_triples(100, 100, triples);
        let p = transition_matrix(&adj);
        let svc = service(4);
        let res = pagerank(&svc, &KernelSpec::coo_nnz(), &p, 0.85, 1e-12, 200).unwrap();
        for i in 1..100 {
            assert!(res.ranks[0] > res.ranks[i], "hub must out-rank leaf {i}");
        }
    }
}
