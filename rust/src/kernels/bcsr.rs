//! BCSR DPU kernel.
//!
//! Blocked formats amortize index overhead: one column index per dense
//! `br x bc` block, one x-gather DMA per block (a contiguous `bc`-element
//! strip of x) instead of one per non-zero, and a tight dense inner loop
//! with no per-element index load. The price is the fill-in zeros
//! (multiplying by zero still costs a MAC on the DPU).
//!
//! Tasklet balancing (paper's `BCSR.block` / `BCSR.nnz`):
//! * `Rows` — equal *block rows* per tasklet (lock-free);
//! * `Nnz` — original-nnz-weighted split at block-row granularity
//!   (lock-free);
//! * `Blocks` — equal *blocks* per tasklet at block granularity: a block
//!   row may be shared between tasklets, so shared block rows take the
//!   chosen [`SyncScheme`] on their y updates.

use super::{acct, DpuKernelOutput, SyncScheme, TaskletBalance};
use crate::matrix::{BcsrMatrix, SpElem};
use crate::partition::balance::{split_elements, split_even, split_weighted};
use crate::pim::{calib, PimConfig, TaskletCounters};

/// Account one dense block's compute: `br*bc` MACs with dense-loop
/// overhead (2 instrs/element) + one x strip gather + block header.
#[inline]
fn block_compute(c: &mut TaskletCounters, br: usize, bc: usize, dt: crate::matrix::DType) {
    c.instrs += calib::BLOCK_LOOP_INSTRS;
    c.instrs += (br * bc) as u64 * (calib::mac_instrs(dt) + 2);
    c.dma(bc * dt.size_bytes()); // contiguous x[col0..col0+bc] gather
}

/// Plan-time per-tasklet split for the BCSR kernel: block ranges plus
/// shared-block-row metadata — computed identically for the
/// single-vector and batched entry points so the two walks (and their
/// accounting) can never drift apart, and cached per work item by the
/// execution plan (the `block_row_of` map alone is an O(nblocks) build
/// per invocation otherwise).
#[derive(Clone, Debug)]
pub struct BcsrSplit {
    /// Tasklet count the split was computed for.
    pub(crate) tasklets: usize,
    ranges: Vec<std::ops::Range<usize>>,
    shares_rows: bool,
    /// Block index -> block row, for detecting shared block rows.
    block_row_of: Vec<u32>,
    /// Distinct shared block rows (lock-free merge epilogue size).
    n_shared: usize,
    /// Per tasklet: (head block row shared with the previous range,
    /// tail shared with the next), `u32::MAX` when unshared.
    shared_bounds: Vec<(u32, u32)>,
}

/// Compute the per-tasklet block split (see [`BcsrSplit`]).
pub fn bcsr_split<T: SpElem>(slice: &BcsrMatrix<T>, t: usize, bal: TaskletBalance) -> BcsrSplit {
    let (br, bc) = (slice.br, slice.bc);
    let nbr = slice.n_block_rows();

    // Map balancing scheme to per-tasklet block index ranges. Blocks of a
    // block row are contiguous in BCSR storage, so block-row-granularity
    // chunks are block ranges too.
    let block_start: Vec<usize> =
        (0..=nbr).map(|i| slice.block_row_ptr[i] as usize).collect();
    let (ranges, shares_rows): (Vec<std::ops::Range<usize>>, bool) = match bal {
        TaskletBalance::Rows => {
            let rc = split_even(nbr, t);
            (rc.iter().map(|r| block_start[r.start]..block_start[r.end]).collect(), false)
        }
        TaskletBalance::Nnz => {
            // Weight block rows by stored values (fill included — that is
            // what the DPU actually computes).
            let weights: Vec<usize> =
                (0..nbr).map(|i| slice.block_row_nblocks(i) * br * bc).collect();
            let rc = split_weighted(&weights, t);
            (rc.iter().map(|r| block_start[r.start]..block_start[r.end]).collect(), false)
        }
        TaskletBalance::Blocks | TaskletBalance::NnzElement => {
            (split_elements(slice.nblocks(), t), true)
        }
    };

    let mut block_row_of = vec![0u32; slice.nblocks()];
    for i in 0..nbr {
        for b in block_start[i]..block_start[i + 1] {
            block_row_of[b] = i as u32;
        }
    }
    // Shared block rows live only at range boundaries (blocks are stored
    // block-row-major), so per-block sharing reduces to two compares —
    // no hash probes in the block loop (§Perf iteration 4).
    let mut n_shared = 0usize;
    let mut shared_bounds: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); t];
    if shares_rows {
        let mut last_shared = u32::MAX;
        for i in 0..ranges.len().saturating_sub(1) {
            let (a, b) = (&ranges[i], &ranges[i + 1]);
            if !a.is_empty() && !b.is_empty() && a.end < slice.nblocks() {
                let row = block_row_of[a.end - 1];
                if row == block_row_of[b.start] {
                    if row != last_shared {
                        n_shared += 1;
                        last_shared = row;
                    }
                    shared_bounds[i].1 = row;
                    shared_bounds[i + 1].0 = row;
                }
            }
        }
    }
    BcsrSplit { tasklets: t, ranges, shares_rows, block_row_of, n_shared, shared_bounds }
}

/// Run the BCSR kernel on one DPU.
pub fn run_bcsr_dpu<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcsrMatrix<T>,
    x: &[T],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    run_bcsr_dpu_cached(cfg, slice, x, &bcsr_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_bcsr_dpu`] with a precomputed [`BcsrSplit`] — the
/// plan-time-split entry point (the execution plan caches one split per
/// work item). `split` must have been computed for `cfg.tasklets`
/// tasklets.
pub fn run_bcsr_dpu_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcsrMatrix<T>,
    x: &[T],
    split: &BcsrSplit,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let (br, bc) = (slice.br, slice.bc);
    let mut y = vec![T::zero(); slice.nrows()];
    let mut counters = vec![TaskletCounters::default(); t];

    let BcsrSplit {
        ranges: block_ranges, shares_rows, block_row_of, n_shared, shared_bounds, ..
    } = split;

    for (tid, range) in block_ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared_bounds[tid];
        // Stream block headers (4B col index each) + dense values.
        acct::stream_matrix(c, range.len() * (4 + br * bc * dt.size_bytes()));
        // Blocks are block-row-major, so distinct block rows in a
        // contiguous range = transitions + 1.
        let mut rows_touched = 0usize;
        let mut current_brow = u32::MAX;
        for bidx in range.clone() {
            let bri_u32 = block_row_of[bidx];
            let bri = bri_u32 as usize;
            if bri_u32 != current_brow {
                current_brow = bri_u32;
                rows_touched += 1;
            }
            let bcol = slice.block_cols[bidx] as usize;
            let blk = &slice.vals[bidx * br * bc..(bidx + 1) * br * bc];
            block_compute(c, br, bc, dt);
            let row0 = bri * br;
            let col0 = bcol * bc;
            let is_shared = bri_u32 == shared_head || bri_u32 == shared_tail;
            for rr in 0..br {
                let r = row0 + rr;
                if r >= slice.nrows() {
                    break;
                }
                let mut acc = T::zero();
                for cc in 0..bc {
                    let ccol = col0 + cc;
                    if ccol >= slice.ncols() {
                        break;
                    }
                    acc = T::mac(acc, blk[rr * bc + cc], x[ccol]);
                }
                if is_shared {
                    acct::locked_update(c, dt, sync);
                }
                y[r] = y[r].add(acc);
            }
        }
        acct::writeback(c, rows_touched * br, dt);
    }

    if *shares_rows && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, *n_shared * br, dt);
    }

    DpuKernelOutput::finish(cfg, y, counters)
}

/// Run the BCSR kernel on one DPU for a whole block of input vectors.
///
/// Fused SpMM-style variant of [`run_bcsr_dpu`]: the block stream is
/// walked once and every vector's accumulator advances per block
/// element, so the host-side simulation streams the slice (and runs the
/// cycle accounting) once per *vector block* instead of once per
/// vector — the same fusion as
/// [`crate::kernels::csr::run_csr_dpu_batch`]. Results are
/// bit-identical to calling [`run_bcsr_dpu`] once per vector: per
/// vector, the MAC chain over each dense block row is evaluated in the
/// same order, and the accounting is structure-only (see `finish_batch`
/// in the module root).
///
/// The tasklet walk below deliberately mirrors [`run_bcsr_dpu`]'s (a
/// shared walk would put a per-element vector loop on the single-vector
/// hot path): any change to the accounting sequence there must be
/// mirrored here, and `tests/batch_equivalence.rs` fails on any drift.
pub fn run_bcsr_dpu_batch<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcsrMatrix<T>,
    xs: &[&[T]],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    run_bcsr_dpu_batch_cached(cfg, slice, xs, &bcsr_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_bcsr_dpu_batch`] with a precomputed [`BcsrSplit`] (see
/// [`run_bcsr_dpu_cached`]).
pub fn run_bcsr_dpu_batch_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &BcsrMatrix<T>,
    xs: &[&[T]],
    split: &BcsrSplit,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    if xs.is_empty() {
        return Vec::new();
    }
    if xs.len() == 1 {
        return vec![run_bcsr_dpu_cached(cfg, slice, xs[0], split, sync)];
    }
    for x in xs {
        assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    }
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let dt = T::DTYPE;
    let (br, bc) = (slice.br, slice.bc);
    let nb = xs.len();
    let mut ys: Vec<Vec<T>> = (0..nb).map(|_| vec![T::zero(); slice.nrows()]).collect();
    let mut counters = vec![TaskletCounters::default(); t];
    let mut accs: Vec<T> = vec![T::zero(); nb];

    let BcsrSplit {
        ranges: block_ranges, shares_rows, block_row_of, n_shared, shared_bounds, ..
    } = split;

    for (tid, range) in block_ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let (shared_head, shared_tail) = shared_bounds[tid];
        acct::stream_matrix(c, range.len() * (4 + br * bc * dt.size_bytes()));
        let mut rows_touched = 0usize;
        let mut current_brow = u32::MAX;
        for bidx in range.clone() {
            let bri_u32 = block_row_of[bidx];
            let bri = bri_u32 as usize;
            if bri_u32 != current_brow {
                current_brow = bri_u32;
                rows_touched += 1;
            }
            let bcol = slice.block_cols[bidx] as usize;
            let blk = &slice.vals[bidx * br * bc..(bidx + 1) * br * bc];
            block_compute(c, br, bc, dt);
            let row0 = bri * br;
            let col0 = bcol * bc;
            let is_shared = bri_u32 == shared_head || bri_u32 == shared_tail;
            for rr in 0..br {
                let r = row0 + rr;
                if r >= slice.nrows() {
                    break;
                }
                accs.fill(T::zero());
                for cc in 0..bc {
                    let ccol = col0 + cc;
                    if ccol >= slice.ncols() {
                        break;
                    }
                    let v = blk[rr * bc + cc];
                    for (b, acc) in accs.iter_mut().enumerate() {
                        *acc = T::mac(*acc, v, xs[b][ccol]);
                    }
                }
                if is_shared {
                    acct::locked_update(c, dt, sync);
                }
                for (b, acc) in accs.iter().enumerate() {
                    ys[b][r] = ys[b][r].add(*acc);
                }
            }
        }
        acct::writeback(c, rows_touched * br, dt);
    }

    if *shares_rows && sync == SyncScheme::LockFree {
        acct::lockfree_merge(&mut counters, *n_shared * br, dt);
    }

    super::finish_batch(cfg, ys, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, CooMatrix, CsrMatrix};

    fn cfg(t: usize) -> PimConfig {
        PimConfig { tasklets: t, ..Default::default() }
    }

    fn check(m: &CooMatrix<f64>, brc: (usize, usize), t: usize, bal: TaskletBalance, sync: SyncScheme) {
        let b = BcsrMatrix::from_coo(m, brc.0, brc.1);
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 5) as f64) - 2.0).collect();
        let out = run_bcsr_dpu(&cfg(t), &b, &x, bal, sync);
        assert_eq!(out.y, m.spmv(&x), "t={t} bal={bal:?} sync={sync:?} blk={brc:?}");
    }

    #[test]
    fn correct_across_schemes_and_blocks() {
        let m = generate::blocked::<f64>(32, 32, 4, 5, 3);
        for brc in [(2, 2), (4, 4), (3, 5)] {
            for t in [1, 4, 16] {
                for bal in [TaskletBalance::Rows, TaskletBalance::Nnz, TaskletBalance::Blocks] {
                    for sync in
                        [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock]
                    {
                        check(&m, brc, t, bal, sync);
                    }
                }
            }
        }
    }

    #[test]
    fn correct_on_unaligned_matrix() {
        let m = generate::scale_free::<f64>(101, 103, 5, 0.5, 7);
        check(&m, (4, 4), 8, TaskletBalance::Blocks, SyncScheme::CoarseLock);
        check(&m, (8, 2), 16, TaskletBalance::Nnz, SyncScheme::LockFree);
    }

    #[test]
    fn fewer_dma_transfers_than_csr() {
        // The point of BCSR on a DPU: one x gather per block, not per nnz.
        let m = generate::blocked::<f64>(64, 64, 4, 8, 5);
        let bcsr = BcsrMatrix::from_coo(&m, 4, 4);
        let csr = CsrMatrix::from_coo(&m);
        let x = vec![1.0; m.ncols()];
        let c = cfg(16);
        let ob = run_bcsr_dpu(&c, &bcsr, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        let oc = crate::kernels::csr::run_csr_dpu(
            &c,
            &csr,
            &x,
            TaskletBalance::Nnz,
            SyncScheme::LockFree,
        );
        let db: u64 = ob.counters.iter().map(|k| k.dma_transfers).sum();
        let dc: u64 = oc.counters.iter().map(|k| k.dma_transfers).sum();
        assert!(db * 2 < dc, "bcsr dma {db} vs csr dma {dc}");
    }

    #[test]
    fn fill_in_costs_compute() {
        // A diagonal matrix blocked 8x8 computes 64x the useful MACs.
        let m = generate::diagonal::<f64>(256, 2);
        let b1 = BcsrMatrix::from_coo(&m, 1, 1);
        let b8 = BcsrMatrix::from_coo(&m, 8, 8);
        let x = vec![1.0; 256];
        let c = cfg(16);
        let o1 = run_bcsr_dpu(&c, &b1, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        let o8 = run_bcsr_dpu(&c, &b8, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        let i1: u64 = o1.counters.iter().map(|k| k.instrs).sum();
        let i8_: u64 = o8.counters.iter().map(|k| k.instrs).sum();
        // A diagonal blocked 8x8 stores 8 values per 1 useful nnz; the
        // dense inner loop pays ~7x the instructions of the 1x1 blocking.
        assert!(i8_ > 5 * i1, "fill-in should inflate instructions: {i8_} vs {i1}");
    }

    #[test]
    fn empty_ok() {
        let m = CooMatrix::<f64>::zeros(16, 16);
        check(&m, (4, 4), 8, TaskletBalance::Blocks, SyncScheme::LockFree);
    }

    #[test]
    fn fused_batch_matches_looped_across_schemes() {
        // Unaligned shape + every (balance, sync) pair: the fused walk
        // must be bit-identical to looped single-vector runs, counters
        // and timing included.
        let m = generate::scale_free::<f64>(60, 52, 5, 0.6, 33);
        let b = BcsrMatrix::from_coo(&m, 4, 4);
        let xs: Vec<Vec<f64>> = (0..7)
            .map(|s| (0..52).map(|i| ((i + 3 * s) % 9) as f64 - 4.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        for bal in [TaskletBalance::Rows, TaskletBalance::Nnz, TaskletBalance::Blocks] {
            for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                let batch = run_bcsr_dpu_batch(&cfg(16), &b, &refs, bal, sync);
                assert_eq!(batch.len(), xs.len());
                for (x, out) in xs.iter().zip(&batch) {
                    let single = run_bcsr_dpu(&cfg(16), &b, x, bal, sync);
                    assert_eq!(out.y, single.y, "{bal:?} {sync:?}: y differs");
                    assert_eq!(out.counters, single.counters, "{bal:?} {sync:?}: counters differ");
                    assert_eq!(out.timing, single.timing, "{bal:?} {sync:?}: timing differs");
                }
            }
        }
        assert!(
            run_bcsr_dpu_batch(&cfg(4), &b, &[], TaskletBalance::Blocks, SyncScheme::LockFree)
                .is_empty()
        );
    }

    #[test]
    fn batch_matches_looped_single_vector() {
        let m = generate::blocked::<f64>(32, 32, 4, 6, 9);
        let b = BcsrMatrix::from_coo(&m, 4, 4);
        let xs: Vec<Vec<f64>> = (0..4)
            .map(|s| (0..32).map(|i| ((i + s) % 5) as f64 - 2.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let batch = run_bcsr_dpu_batch(&cfg(8), &b, &refs, TaskletBalance::Blocks, SyncScheme::CoarseLock);
        assert_eq!(batch.len(), 4);
        for (x, out) in xs.iter().zip(&batch) {
            let single = run_bcsr_dpu(&cfg(8), &b, x, TaskletBalance::Blocks, SyncScheme::CoarseLock);
            assert_eq!(out.y, single.y);
            assert_eq!(out.counters, single.counters);
            assert_eq!(out.timing, single.timing);
        }
    }
}
