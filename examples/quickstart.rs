//! Quickstart: plan one SpMV kernel over the simulated PIM system, then
//! execute it many times — the plan-once/iterate-many shape every
//! iterative app uses.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sparsep::coordinator::{Engine, KernelSpec, SpmvExecutor};
use sparsep::matrix::generate;
use sparsep::pim::PimSystem;

fn main() -> sparsep::util::Result<()> {
    // 1. A sparse matrix. Generators mirror the paper's two matrix
    //    classes; @file.mtx loading is available via matrix::mtx.
    let m = generate::scale_free::<f32>(8192, 8192, 10, 0.6, 42);
    println!(
        "matrix: {}x{}, {} nnz (scale-free class)",
        m.nrows(),
        m.ncols(),
        m.nnz()
    );

    // 2. A PIM system: 256 DPUs, 16 tasklets each (UPMEM defaults). The
    //    threaded engine runs the per-DPU kernel simulations on host
    //    threads; results are bit-identical to the serial engine.
    let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(256), Engine::threaded(0));

    // 3. Plan once: partitioning, per-DPU format conversion and transfer
    //    pricing happen here — never again, however many vectors follow.
    let plan = exec.plan(&KernelSpec::coo_nnz_rgrn(), &m)?;
    println!(
        "plan: {} DPU slices, {} B matrix placed once in {:.3} ms",
        plan.items().len(),
        plan.matrix_bytes(),
        plan.matrix_load_s() * 1e3
    );

    // 4. Execute: exact result + modeled breakdown.
    let x = vec![1.0f32; m.ncols()];
    let run = exec.execute(&plan, &x)?;
    assert_eq!(run.y, m.spmv(&x), "simulator output is exact");
    let b = run.breakdown;
    println!("verified: output matches host oracle");
    println!(
        "breakdown: load {:.3} ms | kernel {:.3} ms | retrieve {:.3} ms ({} dominated)",
        b.load_s * 1e3,
        b.kernel_s * 1e3,
        b.retrieve_s * 1e3,
        b.dominant()
    );
    println!(
        "kernel {:.2} GFLOP/s | e2e {:.2} GFLOP/s | imbalance {:.2}x | energy {:.2e} J",
        run.kernel_gflops(),
        run.e2e_gflops(),
        run.stats.dpu_imbalance,
        run.energy.total_j()
    );

    // 5. Iterate on the same plan (y <- A*y, like a power iteration):
    //    the matrix never moves again, only the vector does.
    let it = exec.run_iterations(&plan, &x, 20)?;
    println!(
        "20 iterations on one plan: {:.3} ms total ({:.3} ms/iter), placement paid once ({:.3} ms)",
        it.total.total_s() * 1e3,
        it.per_iter_s() * 1e3,
        it.last.stats.matrix_load_s * 1e3
    );

    // 6. Batched serving (SpMM-style): a burst of queries against the
    //    resident matrix executes as one engine wave — bit-identical to
    //    looping execute, but the matrix streams once per vector block.
    //    A PlanCache gives the same plan-once behavior to callers with
    //    no place to hold plans (CLI commands, request handlers).
    let cache: sparsep::coordinator::PlanCache<f32> = sparsep::coordinator::PlanCache::new();
    let served = cache.plan(&exec, &KernelSpec::coo_nnz_rgrn(), &m)?;
    let xs: Vec<Vec<f32>> = (0..8)
        .map(|s| (0..m.ncols()).map(|i| ((i + s) % 5) as f32 - 2.0).collect())
        .collect();
    let batch = exec.execute_batch(&served, &xs)?;
    assert_eq!(batch.runs[3].y, m.spmv(&xs[3]), "batched outputs are exact too");
    println!(
        "batched serving: {} vectors in one wave, {:.3} ms modeled total (cache: {} miss, {} hit capacity {})",
        batch.len(),
        batch.total().total_s() * 1e3,
        cache.misses(),
        cache.hits(),
        cache.capacity()
    );

    // 7. The same matrix through every kernel family, one line each.
    println!("\nall-25 sweep (total end-to-end ms):");
    for spec in KernelSpec::all25(8) {
        let p = exec.plan(&spec, &m)?;
        let r = exec.execute(&p, &x)?;
        println!("  {:<14} {:>9.3} ms", spec.name, r.breakdown.total_s() * 1e3);
    }
    Ok(())
}
