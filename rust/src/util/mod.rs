//! Small self-contained utilities.
//!
//! The offline vendor set does not include `rand`, `serde`, `anyhow` or
//! `criterion`, so this module carries a deterministic PRNG, a tiny JSON
//! writer, a minimal error type and a few numeric helpers used across
//! the crate.

pub mod error;
pub mod rng;
pub mod json;
pub mod sync;

pub use error::{Context, Error, ErrorKind, Result};

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Arithmetic mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean); 0 when the mean is 0.
///
/// The paper's matrix-suite analysis keys on the CV of non-zero elements
/// per row to separate "regular" from "scale-free" matrices.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Geometric mean (ignores non-positive entries, as is conventional for
/// speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    let pos: Vec<f64> = xs.iter().copied().filter(|x| *x > 0.0).collect();
    if pos.is_empty() {
        return 0.0;
    }
    (pos.iter().map(|x| x.ln()).sum::<f64>() / pos.len() as f64).exp()
}

/// Format a nanosecond count as a human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Format a byte count with binary prefixes.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{b:.0}B")
    } else if b < K * K {
        format!("{:.1}KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1}MiB", b / (K * K))
    } else {
        format!("{:.2}GiB", b / (K * K * K))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn stats_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
        assert!((cv(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn cv_of_uniform_is_zero() {
        assert_eq!(cv(&[3.0, 3.0, 3.0]), 0.0);
        assert_eq!(cv(&[]), 0.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.0), "12ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
    }
}
