//! Processor-centric baselines for the paper's CPU/GPU comparison
//! (Fig. 16 / Table 3).
//!
//! * [`cpu`] — a real, measured multithreaded CSR SpMV on the host CPU
//!   (the stand-in for the paper's MKL-on-Xeon baseline).
//! * [`roofline`] — analytic fraction-of-peak models for the paper's CPU
//!   and GPU testbeds: SpMV is memory-bound on both, so its attainable
//!   throughput is `bytes-moved-bound`, a tiny fraction of machine peak —
//!   the contrast with PIM that the paper's headline 51.7% figure makes.
//! * The XLA/PJRT accelerator path lives in [`crate::runtime`] and is
//!   exercised by the `cpu_gpu_pim` bench as the "accelerator" code path.

pub mod cpu;
pub mod roofline;
