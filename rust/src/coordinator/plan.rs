//! Execution planning: everything about an SpMV run that depends only on
//! the (matrix, kernel spec, system shape) triple — and *not* on the
//! input vector — captured once in an [`ExecutionPlan`].
//!
//! Iterative applications (CG, Jacobi, PageRank) call SpMV hundreds of
//! times on the same matrix. The paper's methodology accounts for that:
//! matrix placement is a one-time cost, only the input vector moves per
//! iteration. The plan mirrors it in software: partitioning, per-DPU
//! format conversion, transfer sizing and merge metadata are computed
//! here once; [`super::SpmvExecutor::execute`] then only runs kernels
//! and assembles the output.
//!
//! The plan also unifies what used to be three near-duplicate execution
//! paths (1D row-granular, 1D element-granular, 2D tiled) behind one
//! representation: a list of [`WorkItem`]s (per-DPU matrix slice +
//! x-window + y-placement rule) plus precomputed transfer costs.

use super::spec::{KernelSpec, Partitioning};
use crate::kernels::{self, DpuKernelOutput};
use crate::matrix::{BcooMatrix, BcsrMatrix, CooMatrix, CsrMatrix, Format, SpElem};
use crate::partition::balance::{split_elements, split_even, split_weighted};
use crate::partition::TwoDPartitioner;
use crate::pim::{transfer, PimConfig};
use crate::util::Result;
use std::ops::Range;

/// A matrix slice resident in one DPU's MRAM, already converted to the
/// kernel's compressed format (conversion is plan-time work).
#[derive(Clone, Debug)]
pub enum DpuSlice<T: SpElem> {
    Csr(CsrMatrix<T>),
    Coo(CooMatrix<T>),
    Bcsr(BcsrMatrix<T>),
    Bcoo(BcooMatrix<T>),
}

/// One DPU's share of the SpMV: its slice, the window of `x` it reads,
/// and where its output lands in `y`.
#[derive(Clone, Debug)]
pub struct WorkItem<T: SpElem> {
    pub slice: DpuSlice<T>,
    /// Columns of the original matrix this DPU's slice covers (the
    /// x-slice sent to it): the full `0..ncols` for 1D partitionings.
    pub x_range: Range<usize>,
    /// First original row the DPU's output maps to.
    pub y_start: usize,
    /// `false`: this DPU owns its rows exclusively (copy into `y`);
    /// `true`: partial sums that must be added (element-granular
    /// boundary rows, 2D tiles).
    pub accumulate: bool,
    /// Non-zeros in the slice (imbalance accounting).
    pub nnz: usize,
    /// Plan-time per-tasklet split for this slice (computed for the
    /// planning system's tasklet count): kernels consume it instead of
    /// re-running their O(nrows)-and-worse split passes per invocation.
    /// Executors with a *different* tasklet count (tasklet sweeps over
    /// one plan are allowed) recompute on the fly.
    pub(crate) split: kernels::TaskletSplit,
}

/// A reusable execution plan for one (matrix, spec, system) triple.
///
/// Build it once with [`super::SpmvExecutor::plan`], then run
/// [`super::SpmvExecutor::execute`] with as many input vectors as you
/// like — nothing here is recomputed per call.
#[derive(Clone, Debug)]
pub struct ExecutionPlan<T: SpElem> {
    pub spec: KernelSpec,
    /// DPU count the plan was built for (checked at execute time).
    pub n_dpus: usize,
    /// Transfer-pricing inputs the plan's costs were computed under
    /// (checked at execute time: a plan may be executed on a different
    /// executor — e.g. sweeping tasklet counts — but only if the bus
    /// model matches, otherwise the cached load/retrieve pricing would
    /// silently disagree with the executing system).
    pub(crate) dpus_per_rank: usize,
    pub(crate) bus_scale: f64,
    pub(crate) nrows: usize,
    pub(crate) ncols: usize,
    pub(crate) nnz: usize,
    pub(crate) items: Vec<WorkItem<T>>,
    /// One-time matrix placement (scatter of the per-DPU slices).
    pub(crate) mat_load: transfer::TransferCost,
    /// Per-iteration input-vector transfer (broadcast for 1D, scatter of
    /// x-slices for 2D).
    pub(crate) load: transfer::TransferCost,
    /// Per-iteration output gather (same-size padding rule applied).
    pub(crate) retrieve: transfer::TransferCost,
    /// Host-side merge traffic per iteration (duplicated boundary rows
    /// for element-granular 1D, all partials for 2D). Precomputed here —
    /// this used to cost an O(nnz) `row_counts()` pass on *every*
    /// execute of `COO.nnz`.
    pub(crate) merged_bytes: u64,
}

impl<T: SpElem> ExecutionPlan<T> {
    /// Rows of the planned matrix.
    pub fn nrows(&self) -> usize {
        self.nrows
    }
    /// Columns of the planned matrix.
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    /// Non-zeros of the planned matrix.
    pub fn nnz(&self) -> usize {
        self.nnz
    }
    /// The per-DPU work items (slice + x-window + y-placement).
    pub fn items(&self) -> &[WorkItem<T>] {
        &self.items
    }
    /// One-time matrix placement cost, seconds.
    pub fn matrix_load_s(&self) -> f64 {
        self.mat_load.seconds
    }
    /// Total bytes of compressed matrix storage placed on the DPUs.
    pub fn matrix_bytes(&self) -> u64 {
        self.mat_load.payload_bytes
    }

    /// Host-side merge: assemble per-DPU partial outputs into the final
    /// output vector — copy for exclusively-owned 1D row ranges,
    /// accumulate for element-granular boundary rows and 2D tiles.
    /// Shared by the single-vector and batched execution paths, which is
    /// what makes the merge logic batch-aware: a batch merges each
    /// vector's partials through exactly this code, in vector order.
    pub(crate) fn merge_partials(&self, outputs: &[DpuKernelOutput<T>]) -> Vec<T> {
        let mut y = vec![T::zero(); self.nrows];
        self.merge_partials_into(outputs, &mut y);
        y
    }

    /// [`Self::merge_partials`] into a caller-supplied buffer (already
    /// zeroed, length `nrows`) — the request queue's merge stage feeds
    /// recycled buffers from its output pool through here so iterate
    /// requests stop allocating one output vector per iteration.
    pub(crate) fn merge_partials_into(&self, outputs: &[DpuKernelOutput<T>], y: &mut [T]) {
        debug_assert_eq!(y.len(), self.nrows);
        for (item, out) in self.items.iter().zip(outputs) {
            if item.accumulate {
                for (i, v) in out.y.iter().enumerate() {
                    let r = item.y_start + i;
                    y[r] = y[r].add(*v);
                }
            } else {
                y[item.y_start..item.y_start + out.y.len()].copy_from_slice(&out.y);
            }
        }
    }

    /// Execute one SpMV `y = A * x` over this plan on `exec` — the
    /// synchronous execution path (the pipelined serving path is
    /// [`super::SpmvService`]). Results are bit-identical to routing the
    /// same vector through a service.
    pub fn execute(
        &self,
        exec: &super::SpmvExecutor,
        x: &[T],
    ) -> Result<super::RunResult<T>> {
        exec.execute_inner(self, x)
    }

    /// Batched SpMM-style execution with full per-vector metrics: one
    /// [`super::RunResult`] per vector in `xs`, in input order, each
    /// bit-identical to a single-vector [`Self::execute`] of this plan.
    /// The batch is split into [`super::VECTOR_BLOCK`]-sized vector
    /// blocks; every (work-item, block) pair becomes one engine unit.
    pub fn execute_batch_runs(
        &self,
        exec: &super::SpmvExecutor,
        xs: &[Vec<T>],
    ) -> Result<super::BatchResult<T>> {
        exec.execute_batch_inner(self, xs, super::VECTOR_BLOCK)
    }

    /// Iterated SpMV `y <- A*y`, `iters` times starting from `x`
    /// (requires a square matrix for `iters > 1`): the final run plus
    /// cost totals across all iterations.
    pub fn run_iterations(
        &self,
        exec: &super::SpmvExecutor,
        x: &[T],
        iters: usize,
    ) -> Result<super::IterationsResult<T>> {
        exec.run_iterations_inner(self, x, iters)
    }

    /// Iterated batched SpMV: every vector in `xs` independently
    /// self-applied `iters` times, advancing in lockstep (one batched
    /// wave per iteration). Per-vector results are bit-identical to
    /// [`Self::run_iterations`] on each vector alone.
    pub fn run_iterations_batch(
        &self,
        exec: &super::SpmvExecutor,
        xs: &[Vec<T>],
        iters: usize,
    ) -> Result<super::BatchIterationsResult<T>> {
        exec.run_iterations_batch_inner(self, xs, iters, super::VECTOR_BLOCK)
    }

    /// Batched SpMM-style execution `Y = A * X`: multiply this plan's
    /// matrix by every vector in `xs` in one engine wave, returning the
    /// output vectors in input order.
    ///
    /// This is the output-only convenience over
    /// [`Self::execute_batch_runs`] (which additionally returns the
    /// full per-vector metrics): the matrix stays resident in the plan
    /// while any number of right-hand sides stream through. Every
    /// output is bit-identical to a single-vector [`Self::execute`] of
    /// the same plan.
    ///
    /// ```
    /// use sparsep::coordinator::{KernelSpec, SpmvExecutor};
    /// use sparsep::matrix::generate;
    /// use sparsep::pim::PimSystem;
    ///
    /// let m = generate::uniform::<f64>(64, 64, 4, 7);
    /// let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
    /// let plan = exec.plan(&KernelSpec::csr_nnz(), &m).unwrap();
    ///
    /// // Three queries against the resident matrix, one call.
    /// let xs: Vec<Vec<f64>> =
    ///     (0..3).map(|s| vec![s as f64 + 1.0; 64]).collect();
    /// let ys = plan.execute_batch(&exec, &xs).unwrap();
    ///
    /// assert_eq!(ys.len(), 3);
    /// for (x, y) in xs.iter().zip(&ys) {
    ///     assert_eq!(y, &m.spmv(x));
    /// }
    /// ```
    pub fn execute_batch(
        &self,
        exec: &super::SpmvExecutor,
        xs: &[Vec<T>],
    ) -> Result<Vec<Vec<T>>> {
        Ok(self.execute_batch_runs(exec, xs)?.into_ys())
    }
}

/// Convert one COO slice into the spec's format, returning the slice and
/// its storage footprint in bytes (the scatter payload).
fn convert_slice<T: SpElem>(spec: &KernelSpec, coo: CooMatrix<T>) -> (DpuSlice<T>, usize) {
    match spec.format {
        Format::Csr => {
            let csr = CsrMatrix::from_coo(&coo);
            let bytes = csr.size_bytes();
            (DpuSlice::Csr(csr), bytes)
        }
        Format::Coo => {
            let bytes = coo.size_bytes();
            (DpuSlice::Coo(coo), bytes)
        }
        Format::Bcsr => {
            let b = BcsrMatrix::from_coo(&coo, spec.block.0, spec.block.1);
            let bytes = b.size_bytes();
            (DpuSlice::Bcsr(b), bytes)
        }
        Format::Bcoo => {
            let b = BcooMatrix::from_coo(&coo, spec.block.0, spec.block.1);
            let bytes = b.size_bytes();
            (DpuSlice::Bcoo(b), bytes)
        }
    }
}

/// Compute the plan-time tasklet split for one converted slice.
fn split_for<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    slice: &DpuSlice<T>,
) -> kernels::TaskletSplit {
    let (t, bal) = (cfg.tasklets, spec.tasklet_balance);
    match slice {
        DpuSlice::Csr(m) => kernels::TaskletSplit::Csr(kernels::csr::csr_split(m, t, bal)),
        DpuSlice::Coo(m) => kernels::TaskletSplit::Coo(kernels::coo::coo_split(m, t, bal)),
        DpuSlice::Bcsr(m) => kernels::TaskletSplit::Bcsr(kernels::bcsr::bcsr_split(m, t, bal)),
        DpuSlice::Bcoo(m) => kernels::TaskletSplit::Bcoo(kernels::bcoo::bcoo_split(m, t, bal)),
    }
}

/// Run the kernel matching a work item's format on one DPU, consuming
/// the item's plan-time tasklet split when the executing system's
/// tasklet count matches the planned one (the common case); tasklet
/// sweeps over one plan recompute the split on the fly.
pub(crate) fn run_item<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    item: &WorkItem<T>,
    x: &[T],
) -> DpuKernelOutput<T> {
    let xs = &x[item.x_range.clone()];
    let (bal, sync) = (spec.tasklet_balance, spec.sync);
    if item.split.tasklets() != cfg.tasklets {
        return match &item.slice {
            DpuSlice::Csr(m) => kernels::csr::run_csr_dpu(cfg, m, xs, bal, sync),
            DpuSlice::Coo(m) => kernels::coo::run_coo_dpu(cfg, m, xs, bal, sync),
            DpuSlice::Bcsr(m) => kernels::bcsr::run_bcsr_dpu(cfg, m, xs, bal, sync),
            DpuSlice::Bcoo(m) => kernels::bcoo::run_bcoo_dpu(cfg, m, xs, bal, sync),
        };
    }
    match (&item.slice, &item.split) {
        (DpuSlice::Csr(m), kernels::TaskletSplit::Csr(s)) => {
            kernels::csr::run_csr_dpu_cached(cfg, m, xs, s, sync)
        }
        (DpuSlice::Coo(m), kernels::TaskletSplit::Coo(s)) => {
            kernels::coo::run_coo_dpu_cached(cfg, m, xs, s, bal, sync)
        }
        (DpuSlice::Bcsr(m), kernels::TaskletSplit::Bcsr(s)) => {
            kernels::bcsr::run_bcsr_dpu_cached(cfg, m, xs, s, sync)
        }
        (DpuSlice::Bcoo(m), kernels::TaskletSplit::Bcoo(s)) => {
            kernels::bcoo::run_bcoo_dpu_cached(cfg, m, xs, s, sync)
        }
        _ => unreachable!("work-item split format always matches its slice format"),
    }
}

/// Run the batched kernel matching a work item's format on one DPU: one
/// output per input vector, each bit-identical to [`run_item`] on that
/// vector. `xs` holds full-length input vectors; the item's x-window is
/// applied here. The plan-time tasklet split is consumed exactly like
/// [`run_item`] does.
pub(crate) fn run_item_batch<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    item: &WorkItem<T>,
    xs: &[&[T]],
) -> Vec<DpuKernelOutput<T>> {
    let windows: Vec<&[T]> = xs.iter().map(|x| &x[item.x_range.clone()]).collect();
    let (bal, sync) = (spec.tasklet_balance, spec.sync);
    if item.split.tasklets() != cfg.tasklets {
        return match &item.slice {
            DpuSlice::Csr(m) => kernels::csr::run_csr_dpu_batch(cfg, m, &windows, bal, sync),
            DpuSlice::Coo(m) => kernels::coo::run_coo_dpu_batch(cfg, m, &windows, bal, sync),
            DpuSlice::Bcsr(m) => kernels::bcsr::run_bcsr_dpu_batch(cfg, m, &windows, bal, sync),
            DpuSlice::Bcoo(m) => kernels::bcoo::run_bcoo_dpu_batch(cfg, m, &windows, bal, sync),
        };
    }
    match (&item.slice, &item.split) {
        (DpuSlice::Csr(m), kernels::TaskletSplit::Csr(s)) => {
            kernels::csr::run_csr_dpu_batch_cached(cfg, m, &windows, s, sync)
        }
        (DpuSlice::Coo(m), kernels::TaskletSplit::Coo(s)) => {
            kernels::coo::run_coo_dpu_batch_cached(cfg, m, &windows, s, bal, sync)
        }
        (DpuSlice::Bcsr(m), kernels::TaskletSplit::Bcsr(s)) => {
            kernels::bcsr::run_bcsr_dpu_batch_cached(cfg, m, &windows, s, sync)
        }
        (DpuSlice::Bcoo(m), kernels::TaskletSplit::Bcoo(s)) => {
            kernels::bcoo::run_bcoo_dpu_batch_cached(cfg, m, &windows, s, sync)
        }
        _ => unreachable!("work-item split format always matches its slice format"),
    }
}

/// Build the plan for `spec` over `m` on a system shaped by `cfg`.
pub(crate) fn build<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    m: &CooMatrix<T>,
) -> Result<ExecutionPlan<T>> {
    cfg.validate()?;
    match spec.partitioning {
        Partitioning::OneD(bal) => {
            if bal == crate::partition::DpuBalance::NnzElement {
                crate::ensure!(
                    spec.format == Format::Coo,
                    "element-granularity 1D partitioning requires COO (row boundaries are implicit in the other formats)"
                );
                return Ok(build_one_d_elem(cfg, spec, m));
            }
            Ok(build_one_d(cfg, spec, bal, m))
        }
        Partitioning::TwoD(scheme, stripes) => build_two_d(cfg, spec, scheme, stripes, m),
    }
}

// ------------------------------------------------------------------
// 1D: whole rows per DPU + broadcast of the full input vector.
// ------------------------------------------------------------------
fn build_one_d<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    bal: crate::partition::DpuBalance,
    m: &CooMatrix<T>,
) -> ExecutionPlan<T> {
    let n_dpus = cfg.n_dpus;
    let dt = T::DTYPE;

    // Row ranges per DPU. Blocked formats partition at *block-row*
    // granularity so a block row never spans two DPUs.
    let row_ranges: Vec<Range<usize>> = if spec.format.is_blocked() {
        let br = spec.block.0;
        let nbr = crate::util::ceil_div(m.nrows().max(1), br);
        let full = BcsrMatrix::from_coo(m, spec.block.0, spec.block.1);
        let weights: Vec<usize> = match bal {
            crate::partition::DpuBalance::Rows => vec![1; nbr],
            crate::partition::DpuBalance::Blocks => {
                (0..nbr).map(|i| full.block_row_nblocks(i)).collect()
            }
            crate::partition::DpuBalance::Nnz | crate::partition::DpuBalance::NnzElement => {
                (0..nbr)
                    .map(|i| full.block_row_nblocks(i) * spec.block.0 * spec.block.1)
                    .collect()
            }
        };
        let chunks = match bal {
            crate::partition::DpuBalance::Rows => split_even(nbr, n_dpus),
            _ => split_weighted(&weights, n_dpus),
        };
        chunks
            .iter()
            .map(|c| (c.start * br).min(m.nrows())..(c.end * br).min(m.nrows()))
            .collect()
    } else {
        let p = crate::partition::OneDPartitioner::plan_coo(m, n_dpus, bal);
        p.row_ranges
    };

    let mut items = Vec::with_capacity(n_dpus);
    let mut slice_bytes = Vec::with_capacity(n_dpus);
    for range in &row_ranges {
        let coo = m.row_range_slice(range.start, range.end);
        let nnz = coo.nnz();
        let (slice, bytes) = convert_slice(spec, coo);
        slice_bytes.push(bytes);
        let split = split_for(cfg, spec, &slice);
        items.push(WorkItem {
            slice,
            x_range: 0..m.ncols(),
            y_start: range.start,
            accumulate: false,
            nnz,
            split,
        });
    }

    // --- transfer model ---
    // One-time matrix placement (scatter, padded); per-iteration x
    // broadcast; retrieve of each DPU's y range (ragged when balancing
    // by nnz -> padding rule bites).
    let mat_load = transfer::scatter(cfg, &slice_bytes);
    let load = transfer::broadcast(cfg, m.ncols() * dt.size_bytes(), n_dpus);
    let y_sizes: Vec<usize> = row_ranges.iter().map(|r| r.len() * dt.size_bytes()).collect();
    let retrieve = transfer::gather(cfg, &y_sizes);

    ExecutionPlan {
        spec: spec.clone(),
        n_dpus,
        dpus_per_rank: cfg.dpus_per_rank,
        bus_scale: cfg.bus_scale,
        nrows: m.nrows(),
        ncols: m.ncols(),
        nnz: m.nnz(),
        items,
        mat_load,
        load,
        retrieve,
        merged_bytes: 0,
    }
}

// ------------------------------------------------------------------
// 1D at element granularity (`COO.nnz`): equal non-zeros per DPU, rows
// may span two DPUs; boundary partials merged on the host.
// ------------------------------------------------------------------
fn build_one_d_elem<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    m: &CooMatrix<T>,
) -> ExecutionPlan<T> {
    let n_dpus = cfg.n_dpus;
    let dt = T::DTYPE;
    let ranges = split_elements(m.nnz(), n_dpus);

    let mut items = Vec::with_capacity(n_dpus);
    let mut slice_bytes = Vec::with_capacity(n_dpus);
    let mut y_sizes = Vec::with_capacity(n_dpus);
    let mut partial_rows = 0usize;
    for r in &ranges {
        let (slice, first_row) = m.element_range_slice(r.start, r.end);
        let nnz = slice.nnz();
        slice_bytes.push(slice.size_bytes());
        y_sizes.push(slice.nrows() * dt.size_bytes());
        partial_rows += slice.nrows();
        let slice = DpuSlice::Coo(slice);
        let split = split_for(cfg, spec, &slice);
        items.push(WorkItem {
            slice,
            x_range: 0..m.ncols(),
            y_start: first_row,
            accumulate: true,
            nnz,
            split,
        });
    }

    let mat_load = transfer::scatter(cfg, &slice_bytes);
    let load = transfer::broadcast(cfg, m.ncols() * dt.size_bytes(), n_dpus);
    let retrieve = transfer::gather(cfg, &y_sizes);

    // Only the duplicated boundary rows cost merge work. `row_counts`
    // is O(nnz) — one pass here instead of one per execute.
    let covered_rows: usize = m.row_counts().iter().filter(|&&c| c > 0).count();
    let merged_bytes =
        partial_rows.saturating_sub(covered_rows) as u64 * dt.size_bytes() as u64;

    ExecutionPlan {
        spec: spec.clone(),
        n_dpus,
        dpus_per_rank: cfg.dpus_per_rank,
        bus_scale: cfg.bus_scale,
        nrows: m.nrows(),
        ncols: m.ncols(),
        nnz: m.nnz(),
        items,
        mat_load,
        load,
        retrieve,
        merged_bytes,
    }
}

// ------------------------------------------------------------------
// 2D: tiles per DPU, x-slices scattered, partials gathered + merged.
// ------------------------------------------------------------------
fn build_two_d<T: SpElem>(
    cfg: &PimConfig,
    spec: &KernelSpec,
    scheme: crate::partition::TwoDScheme,
    n_col_stripes: usize,
    m: &CooMatrix<T>,
) -> Result<ExecutionPlan<T>> {
    let n_dpus = cfg.n_dpus;
    let dt = T::DTYPE;
    let part = TwoDPartitioner::plan(m, n_dpus, n_col_stripes, scheme)?;

    let mut items = Vec::with_capacity(n_dpus);
    let mut slice_bytes = Vec::with_capacity(n_dpus);
    let mut x_sizes = Vec::with_capacity(n_dpus);
    let mut y_sizes = Vec::with_capacity(n_dpus);
    let mut merged_bytes = 0u64;

    // All stripes in one pass over the matrix (§Perf iteration 7).
    let stripe_ranges: Vec<Range<usize>> = (0..part.n_col_stripes)
        .map(|s| part.tiles[s * part.n_row_tiles].cols.clone())
        .collect();
    let stripes = m.split_col_stripes(&stripe_ranges);
    for s in 0..part.n_col_stripes {
        let stripe_tiles = &part.tiles[s * part.n_row_tiles..(s + 1) * part.n_row_tiles];
        let cr = stripe_tiles[0].cols.clone();
        let stripe = &stripes[s];
        for tile in stripe_tiles {
            let coo = stripe.row_range_slice(tile.rows.start, tile.rows.end);
            let nnz = coo.nnz();
            let (slice, bytes) = convert_slice(spec, coo);
            slice_bytes.push(bytes);
            x_sizes.push(cr.len() * dt.size_bytes());
            y_sizes.push(tile.rows.len() * dt.size_bytes());
            merged_bytes += (tile.rows.len() * dt.size_bytes()) as u64;
            let split = split_for(cfg, spec, &slice);
            items.push(WorkItem {
                slice,
                x_range: cr.clone(),
                y_start: tile.rows.start,
                accumulate: true,
                nnz,
                split,
            });
        }
    }

    // Per-iteration: scatter x-slices (every DPU of a stripe gets the
    // same slice; the runtime still moves one copy per DPU). Retrieve:
    // gather partial y per tile — ragged sizes + padding.
    let mat_load = transfer::scatter(cfg, &slice_bytes);
    let load = transfer::scatter(cfg, &x_sizes);
    let retrieve = transfer::gather(cfg, &y_sizes);

    Ok(ExecutionPlan {
        spec: spec.clone(),
        n_dpus,
        dpus_per_rank: cfg.dpus_per_rank,
        bus_scale: cfg.bus_scale,
        nrows: m.nrows(),
        ncols: m.ncols(),
        nnz: m.nnz(),
        items,
        mat_load,
        load,
        retrieve,
        merged_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    #[test]
    fn one_d_plan_covers_rows_exclusively() {
        let m = generate::uniform::<f64>(300, 300, 6, 3);
        let cfg = PimSystem::with_dpus(8).cfg;
        let p = build(&cfg, &KernelSpec::csr_nnz(), &m).unwrap();
        assert_eq!(p.items().len(), 8);
        assert!(p.items().iter().all(|it| !it.accumulate));
        assert!(p.items().iter().all(|it| it.x_range == (0..300)));
        assert_eq!(p.merged_bytes, 0);
        let total_nnz: usize = p.items().iter().map(|it| it.nnz).sum();
        assert_eq!(total_nnz, m.nnz());
    }

    #[test]
    fn elem_plan_precomputes_merge_metadata() {
        let m = generate::scale_free::<f64>(500, 500, 8, 0.7, 9);
        let cfg = PimSystem::with_dpus(16).cfg;
        let p = build(&cfg, &KernelSpec::coo_nnz(), &m).unwrap();
        assert!(p.items().iter().all(|it| it.accumulate));
        // Boundary rows are duplicated across adjacent DPUs: with 16
        // cuts there are at most 15 shared rows.
        assert!(p.merged_bytes <= 15 * 8);
    }

    #[test]
    fn two_d_plan_slices_x() {
        let m = generate::uniform::<f64>(256, 256, 8, 5);
        let cfg = PimSystem::with_dpus(16).cfg;
        let p = build(&cfg, &KernelSpec::two_d(Format::Coo, 4), &m).unwrap();
        assert_eq!(p.items().len(), 16);
        assert!(p.items().iter().all(|it| it.accumulate));
        assert!(p.items().iter().all(|it| it.x_range.len() == 64));
        assert!(p.merged_bytes > 0);
    }

    #[test]
    fn plan_caches_tasklet_splits_for_every_format() {
        let m = generate::scale_free::<f64>(200, 200, 6, 0.6, 5);
        let cfg = PimSystem::with_dpus(8).cfg;
        for spec in [
            KernelSpec::csr_nnz(),
            KernelSpec::coo_nnz(),
            KernelSpec::bcsr_nnz(),
            KernelSpec::bcoo_nnz(),
            KernelSpec::two_d(Format::Coo, 4),
        ] {
            let p = build(&cfg, &spec, &m).unwrap();
            assert!(
                p.items().iter().all(|it| it.split.tasklets() == cfg.tasklets),
                "{}: every work item must carry a split for the planned tasklet count",
                spec.name
            );
        }
    }

    #[test]
    fn elem_plan_rejects_non_coo() {
        let m = generate::uniform::<f64>(64, 64, 4, 1);
        let cfg = PimSystem::with_dpus(4).cfg;
        let mut spec = KernelSpec::coo_nnz();
        spec.format = Format::Csr;
        assert!(build(&cfg, &spec, &m).is_err());
    }
}
