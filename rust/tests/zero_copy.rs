//! Zero-copy payload tests: the `Arc<[T]>` request payloads introduced
//! by the hot-path overhaul must be *shared*, never copied, end to end:
//!
//! * cloning a [`Request`] — exactly what the sharded dispatcher does
//!   to scatter one request across S shard backends — must yield
//!   pointer-identical payloads ([`Arc::ptr_eq`]);
//! * submitting to the facade must hold the caller's payload by
//!   reference (observable deterministically behind a paused
//!   scheduler via [`Arc::strong_count`]);
//! * the iterate feedback loop never copies: the plain pipeline moves
//!   each iteration's owned output forward, and the sharded gather
//!   wraps it once per iteration so all S shards share one allocation;
//!   a freshly-wrapped payload is uniquely owned.

use sparsep::coordinator::{
    KernelSpec, Request, ServiceBuilder, ShardedService, ShardedServiceBuilder, SpmvService,
};
use sparsep::matrix::generate;
use sparsep::pim::PimSystem;
use std::sync::Arc;

const N: usize = 96;

fn x_vec() -> Vec<f64> {
    (0..N).map(|i| ((i % 7) as f64) - 3.0).collect()
}

/// Poll until the facade's last payload reference is dropped (stage
/// teardown races the response publish by a few instructions). Bounded:
/// a leaked reference must fail the suite with a diagnostic, not hang
/// CI in a silent spin.
fn wait_unique<T>(x: &Arc<[T]>) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while Arc::strong_count(x) > 1 {
        assert!(
            std::time::Instant::now() < deadline,
            "payload still has {} strong references long after completion — a pipeline \
             stage leaked an Arc clone",
            Arc::strong_count(x)
        );
        std::thread::yield_now();
    }
}

#[test]
fn request_clone_shares_payload_allocations() {
    // Request::clone is the scatter primitive: the dispatcher hands one
    // clone per shard. Every payload must be the SAME allocation.
    let x: Arc<[f64]> = x_vec().into();
    let spmv = Request::Spmv { x: Arc::clone(&x) };
    match (&spmv, &spmv.clone()) {
        (Request::Spmv { x: a }, Request::Spmv { x: b }) => {
            assert!(Arc::ptr_eq(a, b), "spmv clone must share the payload");
            assert!(Arc::ptr_eq(a, &x), "request must hold the caller's allocation");
        }
        _ => unreachable!(),
    }

    let xs: Vec<Arc<[f64]>> = (0..4).map(|_| Arc::from(&x_vec()[..])).collect();
    let batch = Request::Batch { xs: xs.clone() };
    match (&batch, &batch.clone()) {
        (Request::Batch { xs: a }, Request::Batch { xs: b }) => {
            for (i, (va, vb)) in a.iter().zip(b.iter()).enumerate() {
                assert!(Arc::ptr_eq(va, vb), "batch clone must share vector {i}");
                assert!(Arc::ptr_eq(va, &xs[i]), "vector {i} must be the caller's allocation");
            }
        }
        _ => unreachable!(),
    }

    let it = Request::Iterate { x: Arc::clone(&x), iters: 3 };
    match (&it, &it.clone()) {
        (Request::Iterate { x: a, .. }, Request::Iterate { x: b, .. }) => {
            assert!(Arc::ptr_eq(a, b), "iterate clone must share the payload");
        }
        _ => unreachable!(),
    }
}

#[test]
fn constructors_wrap_without_extra_references() {
    // Request::spmv(vec) re-wraps an owned vector into a uniquely-owned
    // Arc (strong count 1): no hidden clone is taken anywhere — this is
    // the same re-wrap the iterate feedback performs per iteration.
    let req: Request<f64> = Request::spmv(x_vec());
    match &req {
        Request::Spmv { x } => {
            assert_eq!(Arc::strong_count(x), 1, "fresh payload must be uniquely owned");
            assert_eq!(x.len(), N);
        }
        _ => unreachable!(),
    }
    // An Arc passed through a constructor is shared, not re-copied.
    let x: Arc<[f64]> = x_vec().into();
    match Request::iterate(Arc::clone(&x), 5) {
        Request::Iterate { x: held, iters } => {
            assert_eq!(iters, 5);
            assert!(Arc::ptr_eq(&held, &x), "constructor must keep the caller's allocation");
        }
        _ => unreachable!(),
    }
}

#[test]
fn sharded_spmv_submit_holds_payload_by_reference() {
    let m = generate::scale_free::<f64>(N, N, 5, 0.6, 11);
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(3)
        .start_paused(true)
        .build(PimSystem::with_dpus(4))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
    let x: Arc<[f64]> = x_vec().into();
    let t = svc.submit(h, Request::Spmv { x: Arc::clone(&x) }).unwrap();
    // Queued behind the paused scheduler: the facade holds exactly ONE
    // shared reference — submit copied nothing. (Deterministic: the
    // dispatcher cannot pop while paused.)
    assert_eq!(
        Arc::strong_count(&x),
        2,
        "submit must hold the payload by reference, not copy it"
    );
    svc.resume();
    let r = svc.wait(t).unwrap().into_spmv().unwrap();
    assert_eq!(r.y, m.spmv(&x_vec()));
    // Every scattered sub-request reference is dropped after completion.
    wait_unique(&x);
}

#[test]
fn sharded_batch_submit_shares_every_vector() {
    let m = generate::uniform::<f64>(N, N, 4, 7);
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(2)
        .start_paused(true)
        .build(PimSystem::with_dpus(4))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let xs: Vec<Arc<[f64]>> = (0..5)
        .map(|b| {
            let v: Vec<f64> = (0..N).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect();
            Arc::from(&v[..])
        })
        .collect();
    let t = svc.submit(h, Request::Batch { xs: xs.clone() }).unwrap();
    for (i, x) in xs.iter().enumerate() {
        assert_eq!(
            Arc::strong_count(x),
            2,
            "queued batch must hold vector {i} by reference (ours + the queue's)"
        );
    }
    svc.resume();
    let b = svc.wait(t).unwrap().into_batch().unwrap();
    assert_eq!(b.len(), 5);
    for (x, run) in xs.iter().zip(&b.runs) {
        assert_eq!(run.y, m.spmv(&x.to_vec()));
        wait_unique(x);
    }
}

#[test]
fn plain_service_pipeline_shares_arc_payloads() {
    // The unsharded pipeline threads the submitted Arc through its
    // stages without copying: correctness here, plus the reference is
    // returned once the request completes.
    let m = generate::scale_free::<f64>(N, N, 5, 0.7, 23);
    let svc: SpmvService<f64> =
        ServiceBuilder::new().threads(2).build(PimSystem::with_dpus(8)).unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let x: Arc<[f64]> = x_vec().into();
    let t = svc.submit(h, Request::Spmv { x: Arc::clone(&x) }).unwrap();
    let r = svc.wait(t).unwrap().into_spmv().unwrap();
    assert_eq!(r.y, m.spmv(&x_vec()));
    wait_unique(&x);
}

#[test]
fn iterate_feedback_stays_correct_across_shards_and_engines() {
    // The iterate feedback loop re-wraps each gathered output once and
    // shares it across all shards. The re-wrap must not drift the math:
    // deep iterates through pooled engines and multiple shards stay
    // bit-identical to the host power iteration.
    let m = generate::uniform::<f64>(N, N, 4, 29);
    let mut want = x_vec();
    for _ in 0..6 {
        want = m.spmv(&want);
    }
    for shards in [1usize, 3] {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .threads(2)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        let x: Arc<[f64]> = x_vec().into();
        let t = svc.submit(h, Request::Iterate { x: Arc::clone(&x), iters: 6 }).unwrap();
        let it = svc.wait(t).unwrap().into_iterations().unwrap();
        assert_eq!(it.last.y, want, "shards={shards}");
        assert_eq!(it.iters, 6);
        wait_unique(&x);
    }
}
