#!/usr/bin/env bash
# Static/dynamic concurrency-analysis gates (PR 8). Four named gates:
#
#   1. clippy facade wall — `clippy.toml` forbids raw std::sync
#      primitives and raw thread spawns outside `util::sync`; a canary
#      test file using a raw `std::sync::Mutex` MUST fail the lint
#      (proves the gate actually fires, not just that the tree is clean).
#   2. loom models — `rust/tests/loom_models.rs` explores every
#      interleaving of the four hottest serving-tier protocols under
#      `--cfg loom`. Needs the `loom` crate: the dependency is injected
#      into rust/Cargo.toml for the duration of the run and restored
#      afterwards (the committed manifest stays dependency-free for the
#      offline build).
#   3. Miri — the `taskptr` unit slice (the only unsafe code in the
#      crate) under the interpreter's aliasing/UB checks.
#   4. ThreadSanitizer — the same slice as a data-race check on a
#      nightly toolchain.
#
# Every gate is toolchain-guarded like ci.sh's clippy gate: missing
# components (or no network for the loom crate) skip with a notice
# instead of failing, so the script is runnable in the offline build
# container and does full work on a developer machine.
#
#   scripts/analyze.sh              # all gates
#   SKIP_LOOM=1 scripts/analyze.sh  # skip the loom suite (etc. for
#                                   # SKIP_MIRI, SKIP_TSAN, SKIP_CANARY)
set -euo pipefail
cd "$(dirname "$0")/.."

MANIFEST=rust/Cargo.toml
LOCKFILE=Cargo.lock
CANARY=rust/tests/clippy_canary_disallowed.rs

cleanup() {
  # Restore the pristine manifest/lockfile and drop the canary, no
  # matter how the gates exited.
  if [[ -f "${MANIFEST}.analyze-bak" ]]; then
    mv "${MANIFEST}.analyze-bak" "${MANIFEST}"
  fi
  if [[ -f "${LOCKFILE}.analyze-bak" ]]; then
    mv "${LOCKFILE}.analyze-bak" "${LOCKFILE}"
  elif [[ -f "${LOCKFILE}.analyze-absent" ]]; then
    rm -f "${LOCKFILE}" "${LOCKFILE}.analyze-absent"
  fi
  rm -f "${CANARY}"
}
trap cleanup EXIT

# ---------------------------------------------------------------- 1 --
if [[ "${SKIP_CANARY:-0}" != "1" ]]; then
  echo "== analyze: clippy facade wall (canary must FAIL the lint) =="
  if cargo clippy --version >/dev/null 2>&1; then
    cat > "${CANARY}" <<'EOF'
//! Clippy-gate canary (written by scripts/analyze.sh, never committed):
//! uses a raw std::sync::Mutex outside util::sync. The disallowed-types
//! gate in clippy.toml MUST reject this file; analyze.sh fails if the
//! lint passes it.
#[test]
fn canary_raw_mutex_outside_the_facade() {
    let m = std::sync::Mutex::new(1);
    assert_eq!(*m.lock().unwrap(), 1);
}
EOF
    if cargo clippy --test clippy_canary_disallowed -- -D warnings >/dev/null 2>&1; then
      echo "FAIL: clippy accepted a raw std::sync::Mutex outside util::sync"
      exit 1
    fi
    echo "ok: disallowed-types gate rejects raw std::sync primitives"
    rm -f "${CANARY}"
    echo "== analyze: clippy over the real tree (warnings are errors) =="
    cargo clippy --all-targets -- -D warnings
  else
    echo "clippy component unavailable; skipping facade-wall gate"
  fi
fi

# ---------------------------------------------------------------- 2 --
if [[ "${SKIP_LOOM:-0}" != "1" ]]; then
  echo "== analyze: loom model suite (--cfg loom) =="
  cp "${MANIFEST}" "${MANIFEST}.analyze-bak"
  if [[ -f "${LOCKFILE}" ]]; then
    cp "${LOCKFILE}" "${LOCKFILE}.analyze-bak"
  else
    touch "${LOCKFILE}.analyze-absent"
  fi
  # loom's documented integration: a target-gated dependency that only
  # resolves when RUSTFLAGS carries --cfg loom. Injected temporarily so
  # the committed manifest keeps its empty [dependencies] (the offline
  # container cannot fetch crates).
  cat >> "${MANIFEST}" <<'EOF'

[target.'cfg(loom)'.dependencies]
loom = "0.7"
EOF
  if RUSTFLAGS="--cfg loom" cargo metadata --format-version 1 >/dev/null 2>&1; then
    RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
      cargo test --release --test loom_models
    echo "ok: loom models passed exhaustively"
  else
    echo "loom crate unresolvable (offline registry); skipping loom gate"
  fi
  cleanup
  trap cleanup EXIT
fi

# ---------------------------------------------------------------- 3 --
if [[ "${SKIP_MIRI:-0}" != "1" ]]; then
  echo "== analyze: Miri over the TaskPtr unsafe slice =="
  if cargo miri --version >/dev/null 2>&1; then
    # `miri setup` is idempotent; guard in case the component exists
    # but the sysroot was never built.
    cargo miri setup >/dev/null 2>&1 || true
    if MIRIFLAGS="-Zmiri-strict-provenance" cargo miri test -p sparsep --lib taskptr; then
      echo "ok: Miri found no undefined behavior in the TaskPtr protocol"
    else
      echo "FAIL: Miri reported undefined behavior"
      exit 1
    fi
  else
    echo "miri component unavailable; skipping Miri gate"
  fi
fi

# ---------------------------------------------------------------- 4 --
if [[ "${SKIP_TSAN:-0}" != "1" ]]; then
  echo "== analyze: ThreadSanitizer over the engine/queue unit tests =="
  if rustup run nightly cargo --version >/dev/null 2>&1 \
     && rustup component list --toolchain nightly 2>/dev/null | grep -q '^rust-src.*(installed)'; then
    host="$(rustc -vV | sed -n 's/^host: //p')"
    RUSTFLAGS="-Zsanitizer=thread" \
      rustup run nightly cargo test -Zbuild-std --target "${host}" \
        -p sparsep --lib -- coordinator::engine coordinator::queue
    echo "ok: ThreadSanitizer found no data races"
  else
    echo "nightly toolchain with rust-src unavailable; skipping TSan gate"
  fi
fi

echo "ANALYZE OK"
